//! `bbml-lint` — static enforcement of this repo's hand-written contracts.
//!
//! Six PRs of desk-checked perf work rest on conventions no compiler
//! checks: the PR-2 buffer-ownership rule for `*_into` APIs, zero-alloc
//! hot loops, byte-exact store framing documented in prose, and retained
//! scalar oracles that pin every SWAR/SIMD path. The one real bug shipped
//! so far (the buffer-stealing `signature_into`) was exactly a contract
//! violation no test caught. This module is the mechanical check: a
//! line/token-level scanner (no external parser — consistent with the
//! vendored-deps posture) plus five project rules, driven by
//! `src/bin/bbml-lint.rs` and by fixture self-tests in
//! `tests/integration_lint.rs`.
//!
//! # Rule catalog
//!
//! * **`buffer-contract` (R1)** — a `fn *_into` must take a `&mut`
//!   destination (or a [`RowMut`] bundle), return `()`/`Result<()>`, and
//!   never call `mem::take`/`mem::replace`. Rationale: `_into` names the
//!   in-place reuse contract — "fills the caller's buffer, never steals
//!   its allocation" — and PR 2's `signature_into` showed how silently a
//!   violation turns every reusing call into a fresh allocation.
//!
//! * **`hot-path-alloc` (R2)** — a function annotated
//!   `// bbml-lint: hot-path` may not call `Vec::new`/`vec!`/`to_vec`/
//!   `collect`/`clone`. Rationale: the encode/match kernels are sized so
//!   buffers are allocated once per worker and reused per row; one stray
//!   per-row allocation costs more than the SWAR tricks save.
//!   `reserve`/`clear`/`resize`/`extend_from_slice` on caller buffers are
//!   fine (amortized, capacity survives).
//!
//! * **`no-unwrap` (R3)** — no `unwrap()`/`expect()`/`panic!` in library
//!   code outside `tests/`, `benches/`, `#[cfg(test)]` regions and
//!   `debug_assert` lines. Rationale: the store/training paths return
//!   `io::Result`/`anyhow::Result` end to end so corrupt input is an
//!   error, never an abort; a panic in a pipeline worker poisons the
//!   whole run. Contract checks on programmer error (layout mismatch,
//!   poisoned locks) may stay, suppressed with a reason.
//!
//! * **`format-drift` (R4)** — the byte-layout tables in `store/mod.rs`
//!   docs must agree with the codecs: table rows contiguous,
//!   `HEADER_LEN`/`FRAMED_HEADER_LEN` (`store/format.rs`) and
//!   `FRAME_HEADER_LEN` (`serve/protocol.rs`) equal to the documented
//!   payload offsets, the `MAGIC`/`FRAME_MAGIC` literals and
//!   `VERSION`/`FRAME_VERSION` as documented, and every `out[a..b]` write
//!   in `ShardHeader::encode` / `FrameHeader::encode` matching its
//!   documented (offset, size). A serve protocol without its doc table
//!   (or vice versa) is itself drift. Rationale: the docs are the
//!   interchange spec other tools read; drift between spec and codec is
//!   silent corruption-by-documentation.
//!
//! * **`oracle-retention` (R5)** — every function whose doc comment
//!   declares it a *bit-identity oracle* (or annotated
//!   `// bbml-lint: oracle`) must be referenced from at least one test
//!   (`tests/*.rs` or a `#[cfg(test)]` region). Rationale: every perf
//!   claim here is pinned by a retained reference path; an oracle that no
//!   test calls anymore pins nothing.
//!
//! # Suppressions
//!
//! `// bbml-lint: allow(rule-id) reason: <why>` on (or directly above)
//! the offending line. The reason is mandatory — see [`suppress`].
//! A malformed directive, an unknown rule id, or a missing reason is
//! reported under the `lint-directive` meta-rule.
//!
//! [`RowMut`]: crate::hashing::feature_map::RowMut

pub mod report;
pub mod rules;
pub mod scanner;
pub mod suppress;

use std::io;
use std::path::{Path, PathBuf};

pub use report::{Finding, LintReport};
pub use scanner::SourceFile;

/// Lint in-memory sources: `lib` files get all rules; `tests` files only
/// feed the R5 reference corpus. This is the fixture-test entry point.
pub fn lint_sources(lib: &[(String, String)], tests: &[(String, String)]) -> LintReport {
    let files: Vec<SourceFile> = lib
        .iter()
        .map(|(path, text)| scanner::scan(path, text))
        .collect();
    let test_files: Vec<SourceFile> = tests
        .iter()
        .map(|(path, text)| scanner::scan(path, text))
        .collect();

    // R5 reference corpus: every tests/ code line + every #[cfg(test)]
    // code line of the library.
    let mut corpus: Vec<&str> = Vec::new();
    for f in &test_files {
        for l in &f.lines {
            corpus.push(&l.code);
        }
    }
    for f in &files {
        for l in &f.lines {
            if l.in_test {
                corpus.push(&l.code);
            }
        }
    }

    let mut findings = Vec::new();
    for f in &files {
        findings.extend(rules::check_buffer_contract(f));
        findings.extend(rules::check_hot_path_alloc(f));
        findings.extend(rules::check_no_unwrap(f));
    }
    findings.extend(rules::check_format_drift(&files));
    findings.extend(rules::check_oracle_retention(&files, &corpus));

    let (mut kept, suppressed) = suppress::apply(findings, &files);
    for f in &files {
        kept.extend(suppress::directive_findings(f));
    }
    kept.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));

    LintReport {
        findings: kept,
        suppressed,
        files_scanned: files.len(),
    }
}

/// Recursively collect `.rs` files under `dir` (sorted for determinism),
/// as `(display_path, contents)` pairs. Missing `dir` is an empty set.
fn collect_rs(dir: &Path, strip_prefix: &Path) -> io::Result<Vec<(String, String)>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = match std::fs::read_dir(&d) {
            Ok(e) => e,
            Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
            Err(e) => return Err(e),
        };
        for entry in entries {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                paths.push(p);
            }
        }
    }
    paths.sort();
    let mut out = Vec::new();
    for p in paths {
        let display = p
            .strip_prefix(strip_prefix)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        out.push((display, std::fs::read_to_string(&p)?));
    }
    Ok(out)
}

/// Lint a crate tree: every `.rs` under `<root>/src` is library scope,
/// every `.rs` under `<root>/tests` feeds the R5 reference corpus.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let lib = collect_rs(&root.join("src"), root)?;
    if lib.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("no .rs files under {}/src", root.display()),
        ));
    }
    let tests = collect_rs(&root.join("tests"), root)?;
    Ok(lint_sources(&lib, &tests))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs
            .iter()
            .map(|(p, t)| (p.to_string(), t.to_string()))
            .collect()
    }

    #[test]
    fn clean_sources_produce_clean_report() {
        let rep = lint_sources(
            &src(&[(
                "src/a.rs",
                "pub fn fill_into(out: &mut Vec<u64>) {\n    out.clear();\n}\n",
            )]),
            &[],
        );
        assert!(rep.is_clean(), "{}", rep.render_text());
        assert_eq!(rep.files_scanned, 1);
    }

    #[test]
    fn findings_are_sorted_and_counted() {
        let rep = lint_sources(
            &src(&[(
                "src/a.rs",
                "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\npub fn steal_into(v: &mut Vec<u64>) -> Vec<u64> {\n    std::mem::take(v)\n}\n",
            )]),
            &[],
        );
        assert!(!rep.is_clean());
        assert!(rep.findings.len() >= 3, "{}", rep.render_text());
        let lines: Vec<usize> = rep.findings.iter().map(|f| f.line).collect();
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
    }
}
