//! `bbml-lint` — static enforcement of this repo's hand-written contracts.
//!
//! Eight PRs of desk-checked perf and concurrency work rest on
//! conventions no compiler checks: the PR-2 buffer-ownership rule for
//! `*_into` APIs, zero-alloc hot loops, byte-exact store framing
//! documented in prose, retained scalar oracles pinning every SWAR/SIMD
//! path, and — since the serving subsystem landed — lock ordering and
//! atomic-ordering protocols that exist only in module docs. The one
//! real bug shipped so far (the buffer-stealing `signature_into`) was
//! exactly a contract violation no test caught. This module is the
//! mechanical check: a line/token-level scanner (no external parser —
//! consistent with the vendored-deps posture), a crate-wide symbol table
//! ([`symbols`]) and call graph ([`callgraph`]) built on the same lexer,
//! and nine project rules, driven by `src/bin/bbml-lint.rs` and by
//! fixture self-tests in `tests/integration_lint.rs`.
//!
//! # Rule catalog
//!
//! * **`buffer-contract` (R1)** — a `fn *_into` must take a `&mut`
//!   destination (or a [`RowMut`] bundle), return `()`/`Result<()>`, and
//!   never call `mem::take`/`mem::replace`. Rationale: `_into` names the
//!   in-place reuse contract — "fills the caller's buffer, never steals
//!   its allocation" — and PR 2's `signature_into` showed how silently a
//!   violation turns every reusing call into a fresh allocation.
//!
//! * **`hot-path-alloc` (R2)** — a function annotated
//!   `// bbml-lint: hot-path` may not call `Vec::new`/`vec!`/`to_vec`/
//!   `collect`/`clone` *in its own body*. Rationale: the encode/match
//!   kernels are sized so buffers are allocated once per worker and
//!   reused per row; one stray per-row allocation costs more than the
//!   SWAR tricks save. `reserve`/`clear`/`resize`/`extend_from_slice` on
//!   caller buffers are fine (amortized, capacity survives).
//!
//! * **`no-unwrap` (R3)** — no `unwrap()`/`expect()`/`panic!` in library
//!   code outside `tests/`, `benches/`, `#[cfg(test)]` regions and
//!   `debug_assert` lines. Rationale: the store/training paths return
//!   `io::Result`/`anyhow::Result` end to end so corrupt input is an
//!   error, never an abort; a panic in a pipeline worker poisons the
//!   whole run. Contract checks on programmer error (layout mismatch,
//!   poisoned locks) may stay, suppressed with a reason.
//!
//! * **`format-drift` (R4)** — the byte-layout tables in `store/mod.rs`
//!   docs must agree with the codecs: table rows contiguous and
//!   non-overlapping (two tables merged by a missing blank line is
//!   drift), `HEADER_LEN`/`FRAMED_HEADER_LEN` (`store/format.rs`) and
//!   `FRAME_HEADER_LEN` (`serve/protocol.rs`) equal to the documented
//!   payload offsets, the `MAGIC`/`FRAME_MAGIC` literals and
//!   `VERSION`/`FRAME_VERSION` as documented, and every `out[a..b]` write
//!   in `ShardHeader::encode` / `FrameHeader::encode` matching its
//!   documented (offset, size). A serve protocol without its doc table
//!   (or vice versa) is itself drift. Rationale: the docs are the
//!   interchange spec other tools read; drift between spec and codec is
//!   silent corruption-by-documentation.
//!
//! * **`oracle-retention` (R5)** — every function whose doc comment
//!   declares it a *bit-identity oracle* (or annotated
//!   `// bbml-lint: oracle`) must be referenced from at least one test
//!   (`tests/*.rs` or a `#[cfg(test)]` region). Rationale: every perf
//!   claim here is pinned by a retained reference path; an oracle that no
//!   test calls anymore pins nothing.
//!
//! * **`hot-path-transitive` (R6)** — a `hot-path` function may not
//!   *reach* an allocating function through any call chain, and every
//!   call it makes must resolve in the call graph (an unresolvable callee
//!   in a hot path is itself a finding — "probably fine" is not a
//!   zero-alloc proof). R2 checks the annotated body; R6 closes the
//!   loophole where the allocation hides one call down. Findings name the
//!   chain (`a -> b -> c`) so the fix site is obvious.
//!
//! * **`lock-discipline` (R7)** — guards from `.lock()`/`.read()`/
//!   `.write()` must not be held across blocking calls (file I/O, socket
//!   accept/recv/send, `thread::sleep`, `join`), must not double-acquire
//!   the same lock, and nested acquisitions must follow the declared
//!   crate lock order — [`rules::LOCK_ORDER`], currently
//!   `rx < inner < latency_us < cache < records` (acquire left before
//!   right, never the reverse; a nested pair the order does not cover is
//!   reported too, so the declaration stays total). Checked on the
//!   guard's live range (binding to scope end or `drop`), including
//!   chains reached through the call graph.
//!
//! * **`atomic-ordering` (R8)** — every atomic is classified as a
//!   **gauge** (monitoring counter, no cross-thread protocol;
//!   `Ordering::Relaxed` required) or a **handoff** (publishes state
//!   another thread acts on; `Acquire` loads / `Release` stores /
//!   `AcqRel` RMWs / `(AcqRel, Acquire)` CAS required). Numeric atomics
//!   default to gauge, `AtomicBool` to handoff; override at the
//!   declaration with `// bbml-lint: atomic(gauge)` or
//!   `atomic(handoff)`. Rationale: `SeqCst` sprinkled "to be safe" hides
//!   the actual protocol, and a `Relaxed` stop-flag is a liveness bug on
//!   weakly-ordered targets.
//!
//! * **`float-determinism` (R9)** — in functions reachable from the
//!   training/serving cores (`SgdCore`, `BatchScorer`,
//!   `predict_artifact`): no float accumulation driven by hash-map
//!   iteration order, no float sorts via bare `partial_cmp` (use
//!   `total_cmp`), and no float reductions inside spawned worker
//!   closures. Rationale: run-to-run bit-identity of scores is a project
//!   contract (the serving baselines diff bit-exactly); HashMap iteration
//!   and thread interleaving both break it silently.
//!
//! # Suppressions & directives
//!
//! `// bbml-lint: allow(rule-id) reason: <why>` on (or directly above)
//! the offending line. The reason is mandatory — see [`suppress`].
//! `// bbml-lint: hot-path` / `oracle` annotate functions;
//! `// bbml-lint: atomic(gauge|handoff)` annotates atomic declarations
//! for R8. A malformed directive, an unknown rule id, or a missing
//! reason is reported under the `lint-directive` meta-rule.
//!
//! # Scopes
//!
//! [`lint_sources_scoped`] takes three file sets. **lib** (`src/**`) gets
//! every rule. **exercise** (`benches/**` plus the repo-root `examples/`
//! the manifest points at) gets R1 + R2 + directive hygiene — benches
//! exercise the hot paths, so their buffer and allocation contracts are
//! real, but unwrap-on-setup is idiomatic there. **tests** (`tests/**`)
//! get R1 + directive hygiene and feed the R5 reference corpus. The
//! symbol table and call graph are built over *all three* sets so
//! cross-scope calls resolve, but R6–R9 report only on lib files.
//!
//! [`RowMut`]: crate::hashing::feature_map::RowMut

pub mod callgraph;
pub mod report;
pub mod rules;
pub mod scanner;
pub mod suppress;
pub mod symbols;

use std::io;
use std::path::{Path, PathBuf};

pub use report::{Finding, LintReport};
pub use scanner::SourceFile;

/// Back-compat wrapper: `lib` files get all rules, `tests` files feed the
/// R5 reference corpus, no exercise scope.
pub fn lint_sources(lib: &[(String, String)], tests: &[(String, String)]) -> LintReport {
    lint_sources_scoped(lib, &[], tests)
}

/// Lint in-memory sources under the three-scope model documented in the
/// module docs. This is the fixture-test entry point; [`lint_tree`] maps
/// a crate directory onto it.
pub fn lint_sources_scoped(
    lib: &[(String, String)],
    exercise: &[(String, String)],
    tests: &[(String, String)],
) -> LintReport {
    // One combined scan, lib files first: R6–R9 index files by position
    // and report only on `0..lib_len`, while symbol/call-graph resolution
    // sees every scope.
    let lib_len = lib.len();
    let mut files: Vec<SourceFile> = Vec::with_capacity(lib.len() + exercise.len() + tests.len());
    for (path, text) in lib.iter().chain(exercise) {
        files.push(scanner::scan(path, text));
    }
    let test_start = files.len();
    for (path, text) in tests {
        files.push(scanner::scan(path, text));
    }

    let syms = symbols::build(&files);
    let graph = callgraph::build(&files, &syms);

    // R5 reference corpus: every tests/ code line + every #[cfg(test)]
    // code line of the library.
    let mut corpus: Vec<&str> = Vec::new();
    for f in &files[test_start..] {
        for l in &f.lines {
            corpus.push(&l.code);
        }
    }
    for f in &files[..lib_len] {
        for l in &f.lines {
            if l.in_test {
                corpus.push(&l.code);
            }
        }
    }

    let mut findings = Vec::new();
    for (i, f) in files.iter().enumerate() {
        findings.extend(rules::check_buffer_contract(f));
        if i < test_start {
            findings.extend(rules::check_hot_path_alloc(f));
        }
        if i < lib_len {
            findings.extend(rules::check_no_unwrap(f));
        }
    }
    findings.extend(rules::check_format_drift(&files[..lib_len]));
    findings.extend(rules::check_oracle_retention(&files[..lib_len], &corpus));
    findings.extend(rules::check_hot_path_transitive(&files, lib_len, &graph));
    findings.extend(rules::check_lock_discipline(&files, lib_len, &graph));
    findings.extend(rules::check_atomic_ordering(&files, lib_len, &syms));
    findings.extend(rules::check_float_determinism(&files, lib_len, &syms, &graph));

    let (mut kept, suppressed) = suppress::apply(findings, &files);
    for f in &files {
        kept.extend(suppress::directive_findings(f));
    }
    kept.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));

    LintReport {
        findings: kept,
        suppressed,
        baselined: 0,
        files_scanned: files.len(),
    }
}

/// Recursively collect `.rs` files under `dir` (sorted for determinism),
/// as `(display_path, contents)` pairs. Missing `dir` is an empty set.
fn collect_rs(dir: &Path, strip_prefix: &Path) -> io::Result<Vec<(String, String)>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = match std::fs::read_dir(&d) {
            Ok(e) => e,
            Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
            Err(e) => return Err(e),
        };
        for entry in entries {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                paths.push(p);
            }
        }
    }
    paths.sort();
    let mut out = Vec::new();
    for p in paths {
        let display = p
            .strip_prefix(strip_prefix)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        out.push((display, std::fs::read_to_string(&p)?));
    }
    Ok(out)
}

/// Lint a crate tree: `src/**` is lib scope, `benches/**` plus the
/// examples directory (at `<root>/examples`, else the repo-root
/// `<root>/../examples` the manifest's `path = "../examples/*.rs"`
/// entries point at) are exercise scope, and `tests/**` feeds R1 +
/// the R5 reference corpus.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let lib = collect_rs(&root.join("src"), root)?;
    if lib.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("no .rs files under {}/src", root.display()),
        ));
    }
    let mut exercise = collect_rs(&root.join("benches"), root)?;
    let local_examples = root.join("examples");
    if local_examples.is_dir() {
        exercise.extend(collect_rs(&local_examples, root)?);
    } else if let Some(parent) = root.parent() {
        exercise.extend(collect_rs(&parent.join("examples"), parent)?);
    }
    let tests = collect_rs(&root.join("tests"), root)?;
    Ok(lint_sources_scoped(&lib, &exercise, &tests))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs
            .iter()
            .map(|(p, t)| (p.to_string(), t.to_string()))
            .collect()
    }

    #[test]
    fn clean_sources_produce_clean_report() {
        let rep = lint_sources(
            &src(&[(
                "src/a.rs",
                "pub fn fill_into(out: &mut Vec<u64>) {\n    out.clear();\n}\n",
            )]),
            &[],
        );
        assert!(rep.is_clean(), "{}", rep.render_text());
        assert_eq!(rep.files_scanned, 1);
    }

    #[test]
    fn findings_are_sorted_and_counted() {
        let rep = lint_sources(
            &src(&[(
                "src/a.rs",
                "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\npub fn steal_into(v: &mut Vec<u64>) -> Vec<u64> {\n    std::mem::take(v)\n}\n",
            )]),
            &[],
        );
        assert!(!rep.is_clean());
        assert!(rep.findings.len() >= 3, "{}", rep.render_text());
        let lines: Vec<usize> = rep.findings.iter().map(|f| f.line).collect();
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
    }

    #[test]
    fn exercise_scope_gets_buffer_and_alloc_rules_but_not_unwrap() {
        let bench = "\
// bbml-lint: hot-path
fn measure(out: &mut Vec<u64>) {
    let v: Vec<u64> = Vec::new();
    out.push(v.first().copied().unwrap_or(0));
    let n = std::env::args().next().unwrap();
    let _ = n;
}
fn steal_into(v: &mut Vec<u64>) -> Vec<u64> {
    std::mem::take(v)
}
";
        let rep = lint_sources_scoped(&[], &src(&[("benches/b.rs", bench)]), &[]);
        let rules: Vec<&str> = rep.findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&rules::R2_HOT_PATH_ALLOC), "{rules:?}");
        assert!(rules.contains(&rules::R1_BUFFER_CONTRACT), "{rules:?}");
        assert!(
            !rules.contains(&rules::R3_NO_UNWRAP),
            "benches may unwrap on setup: {rules:?}"
        );
    }

    #[test]
    fn test_scope_is_exempt_from_alloc_and_unwrap_but_not_buffer_contract() {
        let test = "\
#[test]
fn t() {
    let v: Vec<u64> = Vec::new();
    assert_eq!(v.first(), None);
}
fn steal_into(v: &mut Vec<u64>) -> Vec<u64> {
    std::mem::take(v)
}
";
        let rep = lint_sources_scoped(&[], &[], &src(&[("tests/t.rs", test)]));
        let rules: Vec<&str> = rep.findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&rules::R1_BUFFER_CONTRACT), "{rules:?}");
        assert!(!rules.contains(&rules::R3_NO_UNWRAP), "{rules:?}");
        assert!(!rules.contains(&rules::R2_HOT_PATH_ALLOC), "{rules:?}");
    }
}
