//! Crate-wide call graph over the token stream — the engine behind
//! R6 (`hot-path-transitive`), R7's interprocedural lock checks and
//! R9's reachability from the bit-identity surfaces.
//!
//! Extraction is token-level: every `ident(` in non-test code is a call
//! candidate, classified by what precedes it — `.` makes a method call,
//! `::` a path call, anything else a free call — then resolved against
//! the [`SymbolTable`]. Resolution is deliberately conservative:
//!
//! * method calls resolve to *every* impl fn with that name (a union)
//!   unless the receiver is literally `self` and the enclosing impl type
//!   has the method — well-known std method names are excluded first;
//! * path calls are absolutized through the per-file `use` map
//!   (`bbml::`/`crate::`/`self::`/`super::` all normalize), `Type::m`
//!   goes through the impl index, externals (`std::`, `anyhow::`, …)
//!   are terminal;
//! * free calls prefer the enclosing module's own fn (shadowing), then
//!   `use`-imports, then a crate-wide unique name;
//! * calls through fn-typed params or closure-bound locals are *dynamic*
//!   — acknowledged, not resolved (the closure body is analyzed in its
//!   defining function).
//!
//! Anything else inside `crate::` that fails to resolve is kept as
//! [`Callee::Unresolved`] — in a hot-path function that is itself an R6
//! finding, so the graph can never silently drop an edge on the paths
//! that matter.

use std::collections::{HashMap, HashSet};

use super::scanner::SourceFile;
use super::symbols::{FnId, SymbolTable};

/// Resolution of one call site.
#[derive(Debug, Clone, PartialEq)]
pub enum Callee {
    /// One or more crate-internal candidates (a union for ambiguous
    /// method names — every candidate is treated as reachable).
    Resolved(Vec<FnId>),
    /// A std / external-crate call; terminal for every transitive check.
    External,
    /// A call through a fn-typed parameter or closure-bound local.
    Dynamic,
    /// Crate-internal but unresolvable (reason in payload).
    Unresolved(String),
}

/// One extracted call site.
#[derive(Debug)]
pub struct CallSite {
    /// 1-based line of the call.
    pub line: usize,
    /// Callee name as written.
    pub name: String,
    pub callee: Callee,
}

/// Call sites per function, indexed `[file][fn]`.
pub struct CallGraph {
    pub calls: Vec<Vec<Vec<CallSite>>>,
}

/// Method names resolved as std/primitive — never unioned onto crate
/// impls (except through an exact `self.` + impl-type match, which is
/// checked first). Keep sorted for readability; lookup is linear.
const STD_METHODS: &[&str] = &[
    "abs", "accept", "all", "any", "as_bytes", "as_deref", "as_mut", "as_mut_ptr", "as_ptr",
    "as_ref", "as_slice", "as_str", "binary_search", "binary_search_by", "by_ref", "bytes", "cast",
    "ceil", "chain", "chars", "chunks", "chunks_exact", "chunks_exact_mut", "chunks_mut", "clamp",
    "clear", "clone", "cloned", "cmp", "collect", "compare_exchange", "compare_exchange_weak",
    "contains", "contains_key", "copied", "copy_from_slice", "count", "count_ones", "count_zeros",
    "dedup", "display", "drain", "elapsed", "ends_with", "entry", "enumerate", "eq", "exp",
    "extend", "extend_from_slice", "fetch_add", "fetch_and", "fetch_max", "fetch_min", "fetch_or",
    "fetch_sub", "fetch_update", "fetch_xor", "fill", "filter", "filter_map", "find", "find_map",
    "first", "flat_map", "flatten", "floor", "flush", "fold", "for_each", "fract", "get",
    "get_mut", "get_or_insert_with", "hash", "insert", "int", "into", "into_inner", "into_iter",
    "is_char_boundary", "is_dir", "is_empty", "is_file", "is_finite", "is_nan", "is_none",
    "is_ok", "is_some", "iter", "iter_mut", "join", "keys", "kind", "last", "leading_zeros",
    "len", "ln", "load", "lock", "log2", "map", "map_err", "map_or", "max", "max_by",
    "max_by_key", "metadata", "min", "min_by", "min_by_key", "mul_add", "next", "nth", "ok",
    "ok_or", "ok_or_else", "or_else", "or_insert_with", "parse", "partial_cmp", "peek",
    "position", "pow", "powf", "powi", "product", "push", "push_str", "read", "read_exact",
    "read_to_end", "read_to_string", "recv", "recv_timeout", "remove", "repeat", "replace",
    "reserve", "resize", "rev", "rotate_left", "rotate_right", "round", "rsplit", "saturating_add",
    "saturating_mul", "saturating_sub", "send", "set_len", "set_nonblocking", "set_read_timeout",
    "set_write_timeout", "shutdown", "skip", "skip_while", "sort", "sort_by", "sort_by_key",
    "sort_unstable", "sort_unstable_by", "sort_unstable_by_key", "split", "split_at",
    "split_at_mut", "split_first", "split_last", "split_off", "split_whitespace", "sqrt",
    "starts_with", "step_by", "store", "subsec_nanos", "sum", "swap", "swap_remove", "take",
    "take_while", "tan", "tanh", "then", "then_some", "to_le_bytes", "to_lowercase", "to_owned",
    "to_str", "to_string", "to_uppercase", "to_vec", "trailing_zeros", "trim", "trim_end",
    "trim_start", "truncate", "try_clone", "try_into", "unwrap", "unwrap_or", "unwrap_or_default",
    "unwrap_or_else", "unzip", "values", "values_mut", "wait", "windows", "with_capacity",
    "wrapping_add", "wrapping_mul", "wrapping_sub", "write", "write_all", "write_fmt", "zip",
];

/// Keywords that look like `ident(` but are not calls.
const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "loop", "return", "in", "as", "move", "else", "fn", "let",
    "impl", "unsafe", "where", "use", "pub", "mut", "ref", "dyn", "break", "continue", "struct",
    "enum", "trait", "type", "mod", "const", "static", "crate", "super", "await", "yield",
];

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Parameter names of a fn signature (binding idents before each
/// top-level `:` in the param list). The param list is the first `(` at
/// angle depth 0 — generic bounds like `<F: Fn()>` are skipped.
fn param_names(sig: &str) -> Vec<String> {
    let mut angle = 0i64;
    let mut prev = ' ';
    let mut open = None;
    for (i, c) in sig.char_indices() {
        match c {
            '<' => angle += 1,
            '>' if prev != '-' && angle > 0 => angle -= 1,
            '(' if angle == 0 => {
                open = Some(i);
                break;
            }
            _ => {}
        }
        prev = c;
    }
    let Some(open) = open else { return Vec::new() };
    let chars: Vec<char> = sig[open + 1..].chars().collect();
    let mut depth = 0i64;
    let mut end = chars.len();
    for (i, &c) in chars.iter().enumerate() {
        match c {
            '(' | '[' | '<' => depth += 1,
            ')' | ']' | '>' => {
                if c == ')' && depth == 0 {
                    end = i;
                    break;
                }
                depth -= 1;
            }
            _ => {}
        }
    }
    let params: String = chars[..end].iter().collect();
    let mut out = Vec::new();
    let mut d = 0i64;
    let mut start = 0usize;
    let pb: Vec<char> = params.chars().collect();
    for i in 0..=pb.len() {
        let c = pb.get(i).copied().unwrap_or(',');
        match c {
            '(' | '[' | '<' => d += 1,
            ')' | ']' | '>' => d -= 1,
            ',' if d <= 0 => {
                let piece: String = pb[start..i.min(pb.len())].iter().collect();
                if let Some(colon) = piece.find(':') {
                    let name = piece[..colon]
                        .trim()
                        .trim_start_matches("mut ")
                        .trim()
                        .to_string();
                    if name.chars().all(is_ident_char) && !name.is_empty() {
                        out.push(name);
                    }
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    out
}

/// Closure-bound local names in a body line range:
/// `let f = |…|` / `let f = move |…|`.
fn closure_locals(file: &SourceFile, start: usize, end: usize) -> Vec<String> {
    let mut out = Vec::new();
    for line in file.lines.iter().take(end).skip(start.saturating_sub(1)) {
        let code = line.code.trim_start();
        let Some(rest) = code.strip_prefix("let ") else { continue };
        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
        let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
        let Some(eq) = rest.find('=') else { continue };
        let rhs = rest[eq + 1..].trim_start();
        if !name.is_empty() && (rhs.starts_with('|') || rhs.starts_with("move")) {
            out.push(name);
        }
    }
    out
}

/// Line spans of functions nested inside `outer` (their calls belong to
/// the nested item, not to `outer`).
fn nested_spans(file: &SourceFile, outer: usize) -> Vec<(usize, usize)> {
    let Some((os, oe)) = file.functions[outer].body else { return Vec::new() };
    file.functions
        .iter()
        .enumerate()
        .filter(|&(j, f)| {
            j != outer && f.body.is_some_and(|(s, e)| s >= os && e <= oe && (s, e) != (os, oe))
        })
        .map(|(_, f)| (f.line.min(f.body.map(|b| b.0).unwrap_or(f.line)), f.body.map(|b| b.1).unwrap_or(f.line)))
        .collect()
}

/// One raw call candidate on a line: name, its path segments (empty for
/// free/method calls), and whether it is a method call.
struct RawCall {
    name: String,
    segments: Vec<String>,
    method: bool,
    /// For method calls: true when the receiver chain is literally `self`.
    on_self: bool,
}

/// Extract call candidates from one code line.
fn extract_calls(code: &str) -> Vec<RawCall> {
    let chars: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    for (pos, &c) in chars.iter().enumerate() {
        if c != '(' {
            continue;
        }
        let mut j = pos; // exclusive end of the token before `(`
        while j > 0 && chars[j - 1].is_whitespace() {
            j -= 1;
        }
        // Turbofish `::<…>(`: skip the generic args back to the `::`.
        if j > 0 && chars[j - 1] == '>' {
            let mut depth = 0i64;
            let mut k = j;
            while k > 0 {
                match chars[k - 1] {
                    '>' => depth += 1,
                    '<' => {
                        depth -= 1;
                        if depth == 0 {
                            k -= 1;
                            break;
                        }
                    }
                    _ => {}
                }
                k -= 1;
            }
            if k >= 2 && chars[k - 1] == ':' && chars[k - 2] == ':' {
                j = k - 2;
            } else {
                continue;
            }
        }
        if j == 0 {
            continue;
        }
        if chars[j - 1] == '!' {
            continue; // macro invocation
        }
        let mut i = j;
        while i > 0 && is_ident_char(chars[i - 1]) {
            i -= 1;
        }
        if i == j {
            continue; // no ident before `(`
        }
        let name: String = chars[i..j].iter().collect();
        if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            continue;
        }
        if KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        // What precedes the ident?
        let mut segments: Vec<String> = Vec::new();
        let mut method = false;
        let mut on_self = false;
        if i >= 1 && chars[i - 1] == '.' {
            method = true;
            // Receiver chain: is it exactly `self.` (possibly `(self.`)?
            let mut r = i - 1;
            while r > 0 && is_ident_char(chars[r - 1]) {
                r -= 1;
            }
            let recv: String = chars[r..i - 1].iter().collect();
            let before_ok = r == 0 || !matches!(chars[r - 1], '.' | ':');
            on_self = recv == "self" && before_ok;
        } else if i >= 2 && chars[i - 1] == ':' && chars[i - 2] == ':' {
            // Path call: walk segments backwards.
            let mut k = i;
            while k >= 2 && chars[k - 1] == ':' && chars[k - 2] == ':' {
                let mut s = k - 2;
                while s > 0 && is_ident_char(chars[s - 1]) {
                    s -= 1;
                }
                if s == k - 2 {
                    break; // `<T as Trait>::` or similar — stop here
                }
                segments.insert(0, chars[s..k - 2].iter().collect());
                k = s;
            }
            if segments.is_empty() {
                continue; // unparseable qualifier
            }
        } else if i >= 2 && chars[i - 1] == ' ' && chars[..i].iter().collect::<String>().trim_end().ends_with("fn") {
            continue; // the fn item's own name
        }
        out.push(RawCall {
            name,
            segments,
            method,
            on_self,
        });
    }
    out
}

/// Resolve a normalized absolute path call (`segments::name`).
fn resolve_path(
    syms: &SymbolTable,
    file: usize,
    owner: Option<&String>,
    mut segments: Vec<String>,
    name: &str,
) -> Callee {
    // Absolutize the first segment.
    let first = segments[0].clone();
    let abs: String = match first.as_str() {
        "crate" | "bbml" => {
            segments.remove(0);
            "crate".to_string()
        }
        "self" => {
            segments.remove(0);
            syms.module_of[file].clone()
        }
        "super" => {
            let mut m = syms.module_of[file].clone();
            while segments.first().map(String::as_str) == Some("super") {
                segments.remove(0);
                m = match m.rfind("::") {
                    Some(i) => m[..i].to_string(),
                    None => m,
                };
            }
            m
        }
        "Self" => {
            segments.remove(0);
            match owner {
                Some(t) => {
                    segments.insert(0, t.clone());
                    String::new()
                }
                None => return Callee::Unresolved("`Self::` outside an impl block".to_string()),
            }
        }
        _ => match syms.uses.get(file).and_then(|u| u.get(&first)) {
            Some(full) => {
                segments.remove(0);
                full.clone()
            }
            None => String::new(),
        },
    };

    // Type-qualified call: `Type::name` — last segment uppercase.
    let type_seg = segments
        .last()
        .filter(|s| s.chars().next().is_some_and(|c| c.is_ascii_uppercase()))
        .cloned()
        .or_else(|| {
            // `use crate::x::Type; Type::name(…)` — the alias itself
            // resolved to a path ending in an uppercase segment.
            abs.rsplit("::")
                .next()
                .filter(|s| s.chars().next().is_some_and(|c| c.is_ascii_uppercase()))
                .map(str::to_string)
        });
    if let Some(t) = type_seg {
        if let Some(ids) = syms.typed_methods.get(&(t.clone(), name.to_string())) {
            return Callee::Resolved(ids.clone());
        }
        if name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
            return Callee::External; // tuple-variant constructor
        }
        // A crate type we know but a method we don't: associated consts /
        // derived trait methods land here — internal only if the type has
        // any impl at all.
        let known_type = syms.typed_methods.keys().any(|(ty, _)| *ty == t);
        if known_type {
            return Callee::Unresolved(format!("no impl fn `{t}::{name}` found"));
        }
        return Callee::External;
    }

    // Module-path call.
    let full = if abs.is_empty() {
        if segments.is_empty() {
            return Callee::External;
        }
        // Unknown external root (std, io, anyhow, …).
        let root = &segments[0];
        if syms.path_fns.keys().any(|p| p.starts_with(&format!("crate::{root}::"))) {
            format!("crate::{}::{name}", segments.join("::"))
        } else {
            return Callee::External;
        }
    } else if segments.is_empty() {
        format!("{abs}::{name}")
    } else {
        format!("{abs}::{}::{name}", segments.join("::"))
    };
    if !full.starts_with("crate") && !full.starts_with("xbin") && !full.starts_with("xtest") {
        return Callee::External;
    }
    match syms.path_fns.get(&full) {
        Some(ids) => Callee::Resolved(ids.clone()),
        None => Callee::Unresolved(format!("no fn at path `{full}`")),
    }
}

/// Build the call graph for every function in every file.
pub fn build(files: &[SourceFile], syms: &SymbolTable) -> CallGraph {
    let mut calls: Vec<Vec<Vec<CallSite>>> = Vec::with_capacity(files.len());
    for (fi, file) in files.iter().enumerate() {
        let mut per_fn: Vec<Vec<CallSite>> = Vec::with_capacity(file.functions.len());
        for (fj, f) in file.functions.iter().enumerate() {
            let mut sites = Vec::new();
            if let Some((start, end)) = f.body {
                let params = param_names(&f.sig);
                let closures = closure_locals(file, start, end);
                let nested = nested_spans(file, fj);
                let owner = syms.fn_owner[fi][fj].as_ref();
                for (idx, line) in file.lines.iter().enumerate().take(end).skip(start - 1) {
                    let ln = idx + 1;
                    if line.in_test && !f.in_test {
                        continue;
                    }
                    if nested.iter().any(|&(s, e)| s <= ln && ln <= e) {
                        continue;
                    }
                    if line.code.trim_start().starts_with("#[") {
                        continue;
                    }
                    for raw in extract_calls(&line.code) {
                        let callee = if raw.method {
                            resolve_method(syms, owner, &raw)
                        } else if !raw.segments.is_empty() {
                            resolve_path(syms, fi, owner, raw.segments.clone(), &raw.name)
                        } else {
                            resolve_free(syms, fi, &params, &closures, &raw.name)
                        };
                        sites.push(CallSite {
                            line: ln,
                            name: raw.name,
                            callee,
                        });
                    }
                }
            }
            per_fn.push(sites);
        }
        calls.push(per_fn);
    }
    CallGraph { calls }
}

fn resolve_method(syms: &SymbolTable, owner: Option<&String>, raw: &RawCall) -> Callee {
    if raw.on_self {
        if let Some(t) = owner {
            if let Some(ids) = syms.typed_methods.get(&(t.clone(), raw.name.clone())) {
                return Callee::Resolved(ids.clone());
            }
        }
    }
    if STD_METHODS.contains(&raw.name.as_str()) {
        return Callee::External;
    }
    match syms.methods.get(&raw.name) {
        Some(ids) if !ids.is_empty() => Callee::Resolved(ids.clone()),
        _ => Callee::External,
    }
}

fn resolve_free(
    syms: &SymbolTable,
    file: usize,
    params: &[String],
    closures: &[String],
    name: &str,
) -> Callee {
    if params.iter().any(|p| p == name) || closures.iter().any(|c| c == name) {
        return Callee::Dynamic;
    }
    if name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
        return Callee::External; // tuple-struct / enum-variant constructor
    }
    if name == "drop" {
        return Callee::External;
    }
    // Same module first (shadowing), then `use` imports, then a unique
    // crate-wide name.
    let local = format!("{}::{name}", syms.module_of[file]);
    if let Some(ids) = syms.path_fns.get(&local) {
        return Callee::Resolved(ids.clone());
    }
    if let Some(full) = syms.uses.get(file).and_then(|u| u.get(name)) {
        if full.starts_with("crate") {
            return match syms.path_fns.get(full) {
                Some(ids) => Callee::Resolved(ids.clone()),
                None => Callee::Unresolved(format!("imported `{full}` is not a known fn")),
            };
        }
        return Callee::External;
    }
    match syms.free_by_name.get(name).map(Vec::as_slice) {
        Some([id]) => Callee::Resolved(vec![*id]),
        Some(ids) if !ids.is_empty() => Callee::Unresolved(format!(
            "`{name}` is ambiguous ({} crate-wide candidates) — import or qualify it",
            ids.len()
        )),
        _ => Callee::External,
    }
}

impl CallGraph {
    /// All crate-internal targets of a function's call sites.
    pub fn targets(&self, id: FnId) -> impl Iterator<Item = FnId> + '_ {
        self.calls[id.0][id.1].iter().flat_map(|s| match &s.callee {
            Callee::Resolved(ids) => ids.clone(),
            _ => Vec::new(),
        })
    }

    /// Every function reachable from `roots` through resolved edges
    /// (roots included).
    pub fn reachable(&self, roots: &[FnId]) -> HashSet<FnId> {
        let mut seen: HashSet<FnId> = roots.iter().copied().collect();
        let mut stack: Vec<FnId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            for t in self.targets(id) {
                if seen.insert(t) {
                    stack.push(t);
                }
            }
        }
        seen
    }
}

/// Cycle-tolerant memoized DFS: does `direct` hold for `id` or anything
/// it (transitively) calls? Returns the witness chain of fn names from
/// `id` to the first function where `direct` holds, or `None`. A call
/// site is skipped when `skip_site` says so (e.g. reason-suppressed
/// amortized allocations must not taint callers).
///
/// Positive results are always cacheable. A `None` computed while the
/// DFS was cut by a back-edge to an in-progress ancestor might only hold
/// *under that ancestor* — such results are not memoized (`cut` reports
/// the condition upward). A minimal witness path never revisits a node,
/// so the cycle cut can never hide a real chain from a top-level query.
pub fn find_chain(
    graph: &CallGraph,
    files: &[SourceFile],
    id: FnId,
    direct: &dyn Fn(FnId) -> bool,
    skip_site: &dyn Fn(FnId, &CallSite) -> bool,
    memo: &mut HashMap<FnId, Option<Vec<String>>>,
    visiting: &mut HashSet<FnId>,
) -> Option<Vec<String>> {
    find_chain_inner(graph, files, id, direct, skip_site, memo, visiting).0
}

#[allow(clippy::type_complexity)]
fn find_chain_inner(
    graph: &CallGraph,
    files: &[SourceFile],
    id: FnId,
    direct: &dyn Fn(FnId) -> bool,
    skip_site: &dyn Fn(FnId, &CallSite) -> bool,
    memo: &mut HashMap<FnId, Option<Vec<String>>>,
    visiting: &mut HashSet<FnId>,
) -> (Option<Vec<String>>, bool) {
    if let Some(hit) = memo.get(&id) {
        return (hit.clone(), false);
    }
    if !visiting.insert(id) {
        return (None, true); // back-edge: result depends on the ancestor
    }
    let name = files[id.0].functions[id.1].name.clone();
    let mut cut = false;
    let result = if direct(id) {
        Some(vec![name.clone()])
    } else {
        let mut found = None;
        'sites: for site in &graph.calls[id.0][id.1] {
            if skip_site(id, site) {
                continue;
            }
            if let Callee::Resolved(ids) = &site.callee {
                for &t in ids {
                    let (chain, sub_cut) =
                        find_chain_inner(graph, files, t, direct, skip_site, memo, visiting);
                    cut |= sub_cut;
                    if let Some(mut chain) = chain {
                        let mut full = vec![name.clone()];
                        full.append(&mut chain);
                        found = Some(full);
                        break 'sites;
                    }
                }
            }
        }
        found
    };
    visiting.remove(&id);
    if result.is_some() || !cut {
        memo.insert(id, result.clone());
    }
    (result, cut)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan;
    use crate::analysis::symbols;

    fn graph_of(sources: &[(&str, &str)]) -> (Vec<SourceFile>, SymbolTable, CallGraph) {
        let files: Vec<SourceFile> =
            sources.iter().map(|(p, t)| scan(p, t)).collect();
        let syms = symbols::build(&files);
        let graph = build(&files, &syms);
        (files, syms, graph)
    }

    fn fn_id(files: &[SourceFile], name: &str) -> FnId {
        for (fi, f) in files.iter().enumerate() {
            for (fj, func) in f.functions.iter().enumerate() {
                if func.name == name {
                    return (fi, fj);
                }
            }
        }
        panic!("no fn {name}");
    }

    #[test]
    fn cross_module_resolution() {
        let (files, _, graph) = graph_of(&[
            (
                "src/a.rs",
                "use crate::b::helper;\npub fn top() {\n    helper();\n    crate::b::helper2();\n}\n",
            ),
            ("src/b.rs", "pub fn helper() {}\npub fn helper2() {}\n"),
        ]);
        let top = fn_id(&files, "top");
        let targets: Vec<FnId> = graph.targets(top).collect();
        assert_eq!(targets.len(), 2, "{:?}", graph.calls[top.0][top.1]);
        assert!(targets.contains(&fn_id(&files, "helper")));
        assert!(targets.contains(&fn_id(&files, "helper2")));
    }

    #[test]
    fn shadowed_names_prefer_the_local_module() {
        let (files, _, graph) = graph_of(&[
            ("src/a.rs", "fn helper() {}\npub fn top() {\n    helper();\n}\n"),
            ("src/b.rs", "pub fn helper() {}\n"),
        ]);
        let top = fn_id(&files, "top");
        let targets: Vec<FnId> = graph.targets(top).collect();
        assert_eq!(targets, vec![(0, 0)], "must bind to src/a.rs's own helper");
    }

    #[test]
    fn method_and_self_calls_resolve() {
        let (files, _, graph) = graph_of(&[(
            "src/a.rs",
            "pub struct S;\nimpl S {\n    pub fn outer(&self) {\n        self.inner();\n    }\n    fn inner(&self) {}\n}\n",
        )]);
        let outer = fn_id(&files, "outer");
        let targets: Vec<FnId> = graph.targets(outer).collect();
        assert_eq!(targets, vec![fn_id(&files, "inner")]);
    }

    #[test]
    fn dynamic_and_external_calls_are_classified() {
        let (files, _, graph) = graph_of(&[(
            "src/a.rs",
            "pub fn top<F: Fn()>(cb: F) {\n    cb();\n    let local = || ();\n    local();\n    std::fs::read(\"x\").ok();\n    Vec::<u8>::new();\n}\n",
        )]);
        let top = fn_id(&files, "top");
        let sites = &graph.calls[top.0][top.1];
        let kinds: Vec<(&str, &Callee)> =
            sites.iter().map(|s| (s.name.as_str(), &s.callee)).collect();
        assert!(kinds.contains(&("cb", &Callee::Dynamic)), "{kinds:?}");
        assert!(kinds.contains(&("local", &Callee::Dynamic)), "{kinds:?}");
        assert!(kinds.contains(&("read", &Callee::External)), "{kinds:?}");
        assert!(kinds.contains(&("new", &Callee::External)), "{kinds:?}");
    }

    #[test]
    fn cycles_terminate_and_chains_report() {
        let (files, _, graph) = graph_of(&[(
            "src/a.rs",
            "pub fn a() { b(); }\npub fn b() { a(); c(); }\npub fn c() { let v = Vec::new(); drop(v); }\n",
        )]);
        let direct = |id: FnId| {
            let f = &files[id.0].functions[id.1];
            let (s, e) = f.body.unwrap();
            files[id.0].lines[s - 1..e].iter().any(|l| l.code.contains("Vec::new"))
        };
        let mut memo = HashMap::new();
        let chain = find_chain(
            &graph,
            &files,
            fn_id(&files, "a"),
            &direct,
            &|_, _| false,
            &mut memo,
            &mut HashSet::new(),
        );
        assert_eq!(chain, Some(vec!["a".into(), "b".into(), "c".into()]));
    }
}
