//! Lint findings and their renderings (compiler-style text and the
//! `results/LINT_report.json` document).

use std::fmt::Write as _;

/// One lint finding, anchored to a file/line and a rule id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative display path (e.g. `src/hashing/bbit.rs`).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (`buffer-contract`, `hot-path-alloc`, …).
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    /// The compiler-style one-liner: `file:line: rule-id: message`.
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// The result of a lint run.
#[derive(Debug)]
pub struct LintReport {
    /// Kept findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by valid `allow(…) reason: …` directives.
    pub suppressed: usize,
    /// Library files scanned (the rule scope; the test corpus is extra).
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// All findings as text, one per line, plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(out, "{}", f.render());
        }
        let _ = writeln!(
            out,
            "bbml-lint: {} finding{} ({} suppressed) in {} files",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.suppressed,
            self.files_scanned
        );
        out
    }

    /// The JSON document `--json` writes to `results/LINT_report.json`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"tool\": \"bbml-lint\",");
        let _ = writeln!(out, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(out, "  \"suppressed\": {},", self.suppressed);
        let _ = writeln!(out, "  \"finding_count\": {},", self.findings.len());
        out.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"message\": {}}}",
                json_string(&f.file),
                f.line,
                json_string(f.rule),
                json_string(&f.message)
            );
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

/// Minimal JSON string escaping (the vendored-deps posture: no serde).
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compiler_style_lines_and_json() {
        let rep = LintReport {
            findings: vec![Finding {
                file: "src/x.rs".into(),
                line: 7,
                rule: "no-unwrap",
                message: "a \"quoted\" message".into(),
            }],
            suppressed: 2,
            files_scanned: 3,
        };
        let text = rep.render_text();
        assert!(text.starts_with("src/x.rs:7: no-unwrap: "));
        assert!(text.contains("1 finding (2 suppressed) in 3 files"));
        let json = rep.to_json();
        assert!(json.contains("\"finding_count\": 1"));
        assert!(json.contains("\\\"quoted\\\""));
        assert!(!rep.is_clean());
    }

    #[test]
    fn empty_report_is_clean_with_empty_array() {
        let rep = LintReport {
            findings: Vec::new(),
            suppressed: 0,
            files_scanned: 1,
        };
        assert!(rep.is_clean());
        assert!(rep.to_json().contains("\"findings\": []"));
    }
}
