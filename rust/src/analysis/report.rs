//! Lint findings and their renderings (compiler-style text, the
//! `results/LINT_report.json` document, and SARIF 2.1.0 for code-scanning
//! upload), plus baseline support: a committed `LINT_baseline.json` of
//! accepted findings that CI subtracts so only *new* findings fail the
//! build. Baseline entries are matched as a `(file, rule, message)`
//! multiset — line numbers drift with unrelated edits and deliberately do
//! not participate.

use std::fmt::Write as _;

/// One lint finding, anchored to a file/line and a rule id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative display path (e.g. `src/hashing/bbit.rs`).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (`buffer-contract`, `hot-path-alloc`, …).
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    /// The compiler-style one-liner: `file:line: rule-id: message`.
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// The result of a lint run.
#[derive(Debug)]
pub struct LintReport {
    /// Kept findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by valid `allow(…) reason: …` directives.
    pub suppressed: usize,
    /// Findings subtracted by the accepted baseline (`--baseline`).
    pub baselined: usize,
    /// Library files scanned (the rule scope; the test corpus is extra).
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// All findings as text, one per line, plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(out, "{}", f.render());
        }
        let _ = writeln!(
            out,
            "bbml-lint: {} finding{} ({} suppressed, {} baselined) in {} files",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.suppressed,
            self.baselined,
            self.files_scanned
        );
        out
    }

    /// Subtract findings present in a committed baseline document (the
    /// `--json` format). Matching is a `(file, rule, message)` multiset:
    /// each baseline entry cancels at most one live finding, so a rule
    /// regressing from one accepted instance to two still fails. Returns
    /// an error describing the problem when the baseline does not parse.
    pub fn apply_baseline(&mut self, baseline: &str) -> Result<(), String> {
        let mut budget = parse_baseline(baseline)?;
        let mut kept = Vec::new();
        for f in self.findings.drain(..) {
            let key = (f.file.clone(), f.rule.to_string(), f.message.clone());
            if let Some(n) = budget.get_mut(&key) {
                if *n > 0 {
                    *n -= 1;
                    self.baselined += 1;
                    continue;
                }
            }
            kept.push(f);
        }
        self.findings = kept;
        Ok(())
    }

    /// The JSON document `--json` writes to `results/LINT_report.json`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"tool\": \"bbml-lint\",");
        let _ = writeln!(out, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(out, "  \"suppressed\": {},", self.suppressed);
        let _ = writeln!(out, "  \"baselined\": {},", self.baselined);
        let _ = writeln!(out, "  \"finding_count\": {},", self.findings.len());
        out.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"message\": {}}}",
                json_string(&f.file),
                f.line,
                json_string(f.rule),
                json_string(&f.message)
            );
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }

    /// SARIF 2.1.0 document (`--sarif`), the interchange format GitHub
    /// code scanning and most SARIF viewers ingest. One run, one driver,
    /// the full rule catalog, one `result` per finding at `warning`
    /// level (the lint's severity gradient lives in exit codes, not
    /// SARIF levels).
    pub fn to_sarif(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(
            out,
            "  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\","
        );
        let _ = writeln!(out, "  \"version\": \"2.1.0\",");
        out.push_str("  \"runs\": [\n    {\n      \"tool\": {\n        \"driver\": {\n");
        let _ = writeln!(out, "          \"name\": \"bbml-lint\",");
        out.push_str("          \"rules\": [");
        for (i, (id, summary)) in super::rules::RULES.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n            {{\"id\": {}, \"shortDescription\": {{\"text\": {}}}}}",
                json_string(id),
                json_string(summary)
            );
        }
        out.push_str("\n          ]\n        }\n      },\n      \"results\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n        {{\"ruleId\": {}, \"level\": \"warning\", \
                 \"message\": {{\"text\": {}}}, \"locations\": [{{\
                 \"physicalLocation\": {{\"artifactLocation\": {{\"uri\": {}}}, \
                 \"region\": {{\"startLine\": {}}}}}}}]}}",
                json_string(f.rule),
                json_string(&f.message),
                json_string(&f.file),
                f.line
            );
        }
        if !self.findings.is_empty() {
            out.push_str("\n      ");
        }
        out.push_str("]\n    }\n  ]\n}\n");
        out
    }
}

/// Parse a baseline document (the `--json` format) into a multiset of
/// `(file, rule, message)` keys. Hand-rolled like the rest of the tool —
/// the vendored-deps posture rules out serde — but a real recursive
/// object walk, not substring matching, so messages containing braces or
/// quotes round-trip.
fn parse_baseline(
    text: &str,
) -> Result<std::collections::HashMap<(String, String, String), usize>, String> {
    let bytes: Vec<char> = text.chars().collect();
    let mut pos = match text.find("\"findings\"") {
        Some(p) => text[..p].chars().count() + "\"findings\"".chars().count(),
        None => return Err("baseline has no \"findings\" key".into()),
    };
    let skip_ws = |pos: &mut usize, bytes: &[char]| {
        while *pos < bytes.len() && bytes[*pos].is_whitespace() {
            *pos += 1;
        }
    };
    skip_ws(&mut pos, &bytes);
    if pos >= bytes.len() || bytes[pos] != ':' {
        return Err("baseline: expected `:` after \"findings\"".into());
    }
    pos += 1;
    skip_ws(&mut pos, &bytes);
    if pos >= bytes.len() || bytes[pos] != '[' {
        return Err("baseline: expected `[` after \"findings\":".into());
    }
    pos += 1;
    let mut out: std::collections::HashMap<(String, String, String), usize> =
        std::collections::HashMap::new();
    loop {
        skip_ws(&mut pos, &bytes);
        match bytes.get(pos) {
            Some(']') => break,
            Some(',') => {
                pos += 1;
                continue;
            }
            Some('{') => {}
            _ => return Err("baseline: malformed findings array".into()),
        }
        pos += 1; // past '{'
        let mut file = None;
        let mut rule = None;
        let mut message = None;
        loop {
            skip_ws(&mut pos, &bytes);
            match bytes.get(pos) {
                Some('}') => {
                    pos += 1;
                    break;
                }
                Some(',') => {
                    pos += 1;
                    continue;
                }
                Some('"') => {}
                _ => return Err("baseline: malformed finding object".into()),
            }
            let key = parse_json_string(&bytes, &mut pos)?;
            skip_ws(&mut pos, &bytes);
            if bytes.get(pos) != Some(&':') {
                return Err(format!("baseline: expected `:` after key `{key}`"));
            }
            pos += 1;
            skip_ws(&mut pos, &bytes);
            match bytes.get(pos) {
                Some('"') => {
                    let val = parse_json_string(&bytes, &mut pos)?;
                    match key.as_str() {
                        "file" => file = Some(val),
                        "rule" => rule = Some(val),
                        "message" => message = Some(val),
                        _ => {}
                    }
                }
                Some(c) if c.is_ascii_digit() || *c == '-' => {
                    pos += 1;
                    while matches!(bytes.get(pos), Some(c) if c.is_ascii_digit()) {
                        pos += 1;
                    }
                }
                _ => return Err(format!("baseline: unsupported value for key `{key}`")),
            }
        }
        match (file, rule, message) {
            (Some(f), Some(r), Some(m)) => *out.entry((f, r, m)).or_insert(0) += 1,
            _ => return Err("baseline: finding missing file/rule/message".into()),
        }
    }
    Ok(out)
}

/// Parse a JSON string literal at `pos` (which must point at the opening
/// quote); leaves `pos` one past the closing quote.
fn parse_json_string(bytes: &[char], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(bytes.get(*pos), Some(&'"'));
    *pos += 1;
    let mut out = String::new();
    while let Some(&c) = bytes.get(*pos) {
        *pos += 1;
        match c {
            '"' => return Ok(out),
            '\\' => {
                let esc = bytes.get(*pos).copied();
                *pos += 1;
                match esc {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('u') => {
                        let hex: String = bytes.iter().skip(*pos).take(4).collect();
                        *pos += 4;
                        let code = u32::from_str_radix(&hex, 16)
                            .map_err(|_| format!("baseline: bad \\u escape `{hex}`"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("baseline: bad escape in string".into()),
                }
            }
            c => out.push(c),
        }
    }
    Err("baseline: unterminated string".into())
}

/// Minimal JSON string escaping (the vendored-deps posture: no serde).
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compiler_style_lines_and_json() {
        let rep = LintReport {
            findings: vec![Finding {
                file: "src/x.rs".into(),
                line: 7,
                rule: "no-unwrap",
                message: "a \"quoted\" message".into(),
            }],
            suppressed: 2,
            baselined: 0,
            files_scanned: 3,
        };
        let text = rep.render_text();
        assert!(text.starts_with("src/x.rs:7: no-unwrap: "));
        assert!(text.contains("1 finding (2 suppressed, 0 baselined) in 3 files"));
        let json = rep.to_json();
        assert!(json.contains("\"finding_count\": 1"));
        assert!(json.contains("\\\"quoted\\\""));
        assert!(!rep.is_clean());
    }

    #[test]
    fn empty_report_is_clean_with_empty_array() {
        let rep = LintReport {
            findings: Vec::new(),
            suppressed: 0,
            baselined: 0,
            files_scanned: 1,
        };
        assert!(rep.is_clean());
        assert!(rep.to_json().contains("\"findings\": []"));
        let sarif = rep.to_sarif();
        assert!(sarif.contains("\"version\": \"2.1.0\""));
        assert!(sarif.contains("\"results\": []"));
    }

    fn report_with(findings: Vec<Finding>) -> LintReport {
        LintReport {
            findings,
            suppressed: 0,
            baselined: 0,
            files_scanned: 1,
        }
    }

    #[test]
    fn baseline_subtracts_as_a_multiset() {
        let f = |line: usize| Finding {
            file: "src/x.rs".into(),
            line,
            rule: "no-unwrap",
            message: "call `.unwrap()` outside tests".into(),
        };
        // Baseline accepts ONE instance; the live tree has two.
        let baseline = report_with(vec![f(7)]).to_json();
        let mut rep = report_with(vec![f(7), f(40)]);
        rep.apply_baseline(&baseline).expect("baseline parses");
        assert_eq!(rep.baselined, 1);
        assert_eq!(rep.findings.len(), 1, "second instance is NEW and kept");
        // Line drift alone does not un-baseline a finding.
        let mut rep = report_with(vec![f(99)]);
        rep.apply_baseline(&baseline).expect("baseline parses");
        assert_eq!(rep.baselined, 1);
        assert!(rep.is_clean());
    }

    #[test]
    fn baseline_roundtrips_messages_with_quotes_and_braces() {
        let f = Finding {
            file: "src/x.rs".into(),
            line: 3,
            rule: "format-drift",
            message: "rows `{a}` and \"b\" overlap\twide".into(),
        };
        let baseline = report_with(vec![f.clone()]).to_json();
        let mut rep = report_with(vec![f]);
        rep.apply_baseline(&baseline).expect("baseline parses");
        assert!(rep.is_clean());
        assert_eq!(rep.baselined, 1);
    }

    #[test]
    fn malformed_baseline_is_an_error_not_a_pass() {
        let mut rep = report_with(Vec::new());
        assert!(rep.apply_baseline("{}").is_err());
        assert!(rep.apply_baseline("{\"findings\": [{\"file\": 3}]}").is_err());
    }

    #[test]
    fn sarif_carries_rule_catalog_and_locations() {
        let rep = report_with(vec![Finding {
            file: "src/x.rs".into(),
            line: 7,
            rule: "no-unwrap",
            message: "msg".into(),
        }]);
        let sarif = rep.to_sarif();
        assert!(sarif.contains("\"name\": \"bbml-lint\""));
        assert!(sarif.contains("\"id\": \"hot-path-transitive\""));
        assert!(sarif.contains("\"ruleId\": \"no-unwrap\""));
        assert!(sarif.contains("\"startLine\": 7"));
        assert!(sarif.contains("\"uri\": \"src/x.rs\""));
    }
}
