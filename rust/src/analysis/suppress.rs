//! Suppression handling: `// bbml-lint: allow(rule-id) reason: …`.
//!
//! An allow directive silences findings of `rule-id` on its **target
//! line** — the directive's own line when it trails code, otherwise the
//! next line carrying code (so it sits directly above the offending
//! statement, or directly above a `fn` line for function-anchored rules;
//! attribute lines count as code, so place the comment *after* any
//! attributes). The reason is mandatory: a reason-less allow suppresses
//! nothing and is itself reported, as is an allow naming an unknown rule.
//! This keeps every suppression greppable and self-justifying — the
//! lint's findings can be silenced, but never silently.

use super::report::Finding;
use super::rules::{self, LINT_DIRECTIVE};
use super::scanner::{DirectiveKind, SourceFile};

/// True when `rule` is one of the enforceable rule ids.
fn known_rule(rule: &str) -> bool {
    rules::RULES.iter().any(|(id, _)| *id == rule)
}

/// Findings about the directives themselves: malformed payloads, unknown
/// rule ids, missing reasons. These are not suppressible.
pub fn directive_findings(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for d in &file.directives {
        let message = match &d.kind {
            DirectiveKind::Malformed(text) => Some(format!(
                "unrecognized bbml-lint directive `{text}` — expected `hot-path`, \
                 `oracle`, `atomic(gauge|handoff)`, or `allow(rule-id) reason: …`"
            )),
            DirectiveKind::Allow { rule, reason } => {
                if !known_rule(rule) {
                    Some(format!(
                        "allow names unknown rule `{rule}` — known rules: {}",
                        rules::RULES
                            .iter()
                            .map(|(id, _)| *id)
                            .collect::<Vec<_>>()
                            .join(", ")
                    ))
                } else if reason.is_none() {
                    Some(format!(
                        "allow({rule}) has no reason — a suppression must justify \
                         itself: `// bbml-lint: allow({rule}) reason: …`"
                    ))
                } else {
                    None
                }
            }
            DirectiveKind::HotPath | DirectiveKind::Oracle | DirectiveKind::Atomic(_) => None,
        };
        if let Some(message) = message {
            out.push(Finding {
                file: file.path.clone(),
                line: d.line,
                rule: LINT_DIRECTIVE,
                message,
            });
        }
    }
    out
}

/// Drop findings covered by a valid allow directive. Returns the kept
/// findings and the number suppressed.
pub fn apply(findings: Vec<Finding>, files: &[SourceFile]) -> (Vec<Finding>, usize) {
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        let covered = files
            .iter()
            .filter(|file| file.path == f.file)
            .flat_map(|file| file.directives.iter())
            .any(|d| match &d.kind {
                DirectiveKind::Allow {
                    rule,
                    reason: Some(_),
                } => rule == f.rule && d.target_line == f.line && known_rule(rule),
                _ => false,
            });
        if covered {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    (kept, suppressed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::check_no_unwrap;
    use crate::analysis::scanner::scan;

    #[test]
    fn valid_allow_suppresses() {
        let src = "\
// bbml-lint: allow(no-unwrap) reason: infallible by construction
let a = x.unwrap();
";
        let f = scan("x.rs", src);
        let findings = check_no_unwrap(&f);
        assert_eq!(findings.len(), 1);
        let files = vec![f];
        let (kept, suppressed) = apply(findings, &files);
        assert!(kept.is_empty());
        assert_eq!(suppressed, 1);
        assert!(directive_findings(&files[0]).is_empty());
    }

    #[test]
    fn reasonless_allow_reports_and_does_not_suppress() {
        let src = "\
// bbml-lint: allow(no-unwrap)
let a = x.unwrap();
";
        let f = scan("x.rs", src);
        let findings = check_no_unwrap(&f);
        let files = vec![f];
        let (kept, suppressed) = apply(findings, &files);
        assert_eq!(kept.len(), 1, "reason-less allow must not suppress");
        assert_eq!(suppressed, 0);
        let dirs = directive_findings(&files[0]);
        assert_eq!(dirs.len(), 1);
        assert!(dirs[0].message.contains("no reason"));
    }

    #[test]
    fn unknown_rule_is_reported() {
        let f = scan("x.rs", "// bbml-lint: allow(no-such-rule) reason: because\n");
        let dirs = directive_findings(&f);
        assert_eq!(dirs.len(), 1);
        assert!(dirs[0].message.contains("unknown rule"));
    }
}
