//! Evaluation metrics shared by the trainer and the experiment harness.

/// Binary-classification summary.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Confusion {
    pub tp: usize,
    pub tn: usize,
    pub fp: usize,
    pub fn_: usize,
}

impl Confusion {
    /// Tally predictions against ±1 labels.
    pub fn from_predictions(scores: &[f64], labels: &[f32]) -> Self {
        assert_eq!(scores.len(), labels.len());
        let mut c = Confusion::default();
        for (&s, &y) in scores.iter().zip(labels) {
            match (s >= 0.0, y > 0.0) {
                (true, true) => c.tp += 1,
                (false, false) => c.tn += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn total(&self) -> usize {
        self.tp + self.tn + self.fp + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

/// Mean and sample standard deviation of a series (the paper reports both
/// across 50 repetitions — Figs. 1/2, 5/6).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_tallies_correctly() {
        let scores = [1.0, -1.0, 1.0, -2.0];
        let labels = [1.0f32, -1.0, -1.0, 1.0];
        let c = Confusion::from_predictions(&scores, &labels);
        assert_eq!(c, Confusion { tp: 1, tn: 1, fp: 1, fn_: 1 });
        assert_eq!(c.accuracy(), 0.5);
        assert_eq!(c.precision(), 0.5);
        assert_eq!(c.recall(), 0.5);
        assert_eq!(c.f1(), 0.5);
    }

    #[test]
    fn degenerate_cases_do_not_divide_by_zero() {
        let c = Confusion::default();
        assert_eq!(c.accuracy(), 0.0);
        assert_eq!(c.precision(), 0.0);
        assert_eq!(c.recall(), 0.0);
        assert_eq!(c.f1(), 0.0);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[3.0]), (3.0, 0.0));
    }
}
