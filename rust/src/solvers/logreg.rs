//! L2-regularized logistic regression via dual coordinate descent
//! (Yu, Huang & Lin, "Dual coordinate descent methods for logistic
//! regression and maximum entropy models" — LIBLINEAR's `-s 7`), used by
//! the paper's §5.3 experiments.
//!
//! Primal (paper eq. 10):  min_w ½‖w‖² + C Σ log(1 + exp(−y_i w·x_i)).
//! Dual: min_α ½‖w(α)‖² + Σ [α_i log α_i + (C−α_i) log(C−α_i)] over
//! 0 < α_i < C with w(α) = Σ α_i y_i x_i. Per-coordinate we run a few
//! guarded Newton steps on
//!
//!   g(α) = y_i·w·x_i + log(α/(C−α)),   g'(α) = Q_ii + C/(α(C−α)).

use super::{Features, LinearModel};
use crate::rng::Xoshiro256;

/// Solver options.
#[derive(Clone, Debug)]
pub struct LogRegOptions {
    pub c: f64,
    pub max_iter: usize,
    /// Stop when the max |g| seen in an epoch < tol.
    pub tol: f64,
    /// Inner Newton iterations per coordinate.
    pub newton_steps: usize,
    pub seed: u64,
}

impl Default for LogRegOptions {
    fn default() -> Self {
        Self {
            c: 1.0,
            max_iter: 100,
            tol: 1e-3,
            newton_steps: 8,
            seed: 1,
        }
    }
}

/// Train L2-regularized logistic regression by dual coordinate descent.
/// Generic over [`Features`] — any hashing scheme's output trains here.
pub fn train_logreg<Ft: Features>(feats: &Ft, opt: &LogRegOptions) -> LinearModel {
    let n = feats.n();
    let dim = feats.dim();
    assert!(n > 0, "empty training set");
    let c = opt.c;
    let eps_box = 1e-12 * c; // keep α strictly inside (0, C)

    let mut w = vec![0.0f32; dim];
    // Initialize α interior (LIBLINEAR uses min(εC, ...) — C/2 also works;
    // we follow the common α = C/2 warm start scaled down for stability).
    let alpha0 = (0.1 * c).min(0.5 * c);
    let mut alpha = vec![alpha0; n];
    for i in 0..n {
        feats.axpy(i, alpha[i] * feats.label(i) as f64, &mut w);
    }
    let qd: Vec<f64> = (0..n).map(|i| feats.row_norm_sq(i)).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256::seed_from_u64(opt.seed);

    let mut epochs = 0;
    for epoch in 0..opt.max_iter {
        epochs = epoch + 1;
        rng.shuffle(&mut order);
        let mut max_g: f64 = 0.0;
        for &i in &order {
            if qd[i] == 0.0 {
                continue;
            }
            let y = feats.label(i) as f64;
            let mut a = alpha[i];
            let wx = y * feats.dot(i, &w);
            // Newton on g(a) = wx − Q_ii·α_old·?  — careful: w already
            // contains α_i's contribution; g uses the *current* w(α), so
            // as `a` moves within the inner loop the margin term moves by
            // Q_ii·(a − α_i)·y²  = Q_ii·(a − α_i).
            let mut g_first = None;
            for _ in 0..opt.newton_steps {
                let g = wx + qd[i] * (a - alpha[i]) + (a / (c - a)).ln();
                if g_first.is_none() {
                    g_first = Some(g.abs());
                }
                let h = qd[i] + c / (a * (c - a));
                let mut step = g / h;
                // Guard the Newton step inside the open box.
                let mut a_new = a - step;
                while a_new <= 0.0 || a_new >= c {
                    step *= 0.5;
                    a_new = a - step;
                    if step.abs() < 1e-300 {
                        a_new = a;
                        break;
                    }
                }
                if (a_new - a).abs() < 1e-15 * c {
                    a = a_new;
                    break;
                }
                a = a_new;
            }
            max_g = max_g.max(g_first.unwrap_or(0.0));
            let a = a.clamp(eps_box, c - eps_box);
            let delta = (a - alpha[i]) * y;
            if delta != 0.0 {
                feats.axpy(i, delta, &mut w);
                alpha[i] = a;
            }
        }
        if max_g < opt.tol {
            break;
        }
    }

    let objective = primal_objective(feats, &w, c);
    LinearModel {
        w,
        iters: epochs,
        objective,
    }
}

/// Primal objective of eq. (10) at w.
pub fn primal_objective<Ft: Features>(feats: &Ft, w: &[f32], c: f64) -> f64 {
    let reg: f64 = 0.5 * w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
    let mut loss = 0.0;
    for i in 0..feats.n() {
        let m = feats.label(i) as f64 * feats.dot(i, w);
        // log(1 + e^{−m}) computed stably.
        loss += if m > 0.0 {
            (-m).exp().ln_1p()
        } else {
            -m + m.exp().ln_1p()
        };
    }
    reg + c * loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
    use crate::rng::Xoshiro256;

    fn toy(n: usize, dim: u64, seed: u64) -> SparseBinaryDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ds = SparseBinaryDataset::new(dim);
        for i in 0..n {
            let pos = i % 2 == 0;
            let mut idx = vec![if pos { 0u64 } else { 1u64 }];
            for _ in 0..5 {
                idx.push(2 + rng.gen_range(dim - 2));
            }
            ds.push(
                SparseBinaryVec::from_indices(idx),
                if pos { 1.0 } else { -1.0 },
            );
        }
        ds
    }

    #[test]
    fn separable_data_classified_perfectly() {
        let ds = toy(200, 100, 3);
        let model = train_logreg(&ds, &LogRegOptions::default());
        assert_eq!(model.accuracy(&ds), 1.0);
    }

    #[test]
    fn matches_gradient_descent_reference_on_small_problem() {
        // Cross-check the DCD optimum against a long plain-GD run on the
        // primal objective (both should reach the same unique minimum).
        let ds = toy(60, 30, 11);
        let c = 0.7;
        let model = train_logreg(
            &ds,
            &LogRegOptions {
                c,
                max_iter: 300,
                tol: 1e-8,
                ..Default::default()
            },
        );
        // Reference GD.
        let dim = 30usize;
        let mut w = vec![0.0f32; dim];
        let lr = 0.05;
        for _ in 0..8000 {
            let mut grad = vec![0.0f64; dim];
            for (i, g) in grad.iter_mut().enumerate() {
                *g = w[i] as f64;
            }
            for i in 0..ds.n() {
                let y = ds.label(i) as f64;
                let m = y * ds.dot(i, &w);
                let sigma = 1.0 / (1.0 + m.exp());
                let coef = -c * y * sigma;
                for &idx in ds.row(i) {
                    grad[idx as usize] += coef;
                }
            }
            for (wi, g) in w.iter_mut().zip(&grad) {
                *wi -= (lr * g) as f32;
            }
        }
        let obj_gd = primal_objective(&ds, &w, c);
        assert!(
            (model.objective - obj_gd).abs() / obj_gd < 0.01,
            "DCD {} vs GD {}",
            model.objective,
            obj_gd
        );
    }

    #[test]
    fn larger_c_fits_training_data_harder() {
        let ds = toy(200, 500, 5);
        let loose = train_logreg(
            &ds,
            &LogRegOptions {
                c: 1e-3,
                ..Default::default()
            },
        );
        let tight = train_logreg(
            &ds,
            &LogRegOptions {
                c: 10.0,
                ..Default::default()
            },
        );
        // Training loss term must be lower for large C.
        let lt = primal_objective(&ds, &tight.w, 1.0) - 0.5 * tight.w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
        let ll = primal_objective(&ds, &loose.w, 1.0) - 0.5 * loose.w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
        assert!(lt < ll, "{lt} !< {ll}");
    }

    #[test]
    fn objective_near_log2n_at_c_to_zero() {
        // As C → 0, w → 0 and the objective → C·n·log 2.
        let ds = toy(50, 20, 9);
        let c = 1e-6;
        let model = train_logreg(
            &ds,
            &LogRegOptions {
                c,
                ..Default::default()
            },
        );
        let expect = c * 50.0 * std::f64::consts::LN_2;
        assert!(
            (model.objective - expect).abs() < 0.5 * expect + 1e-9,
            "{} vs {}",
            model.objective,
            expect
        );
    }

    #[test]
    fn weights_are_finite() {
        let ds = toy(100, 50, 13);
        for c in [1e-3, 1.0, 100.0] {
            let model = train_logreg(
                &ds,
                &LogRegOptions {
                    c,
                    ..Default::default()
                },
            );
            assert!(model.w.iter().all(|x| x.is_finite()), "C={c}");
        }
    }
}
