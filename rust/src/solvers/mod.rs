//! Learning algorithms (paper §4–§5): the training substrate the paper runs
//! on top of LIBLINEAR/LIBSVM, re-implemented from scratch.
//!
//! * [`linear_svm`] — dual coordinate descent for L1-/L2-loss linear SVM
//!   (Hsieh et al., ICML 2008 — LIBLINEAR's `-s 3`/`-s 1`).
//! * [`logreg`] — dual coordinate descent for L2-regularized logistic
//!   regression (Yu, Huang, Lin — LIBLINEAR's `-s 7`).
//! * [`sgd`] — Pegasos-style stochastic subgradient SVM (the paper cites
//!   Pegasos/Bottou SGD as the representative solver family).
//! * [`kernel_svm`] — SMO-style dual solver over an arbitrary kernel with
//!   a row cache; used with the resemblance / b-bit estimated kernels for
//!   the paper's §5.1 nonlinear experiments.
//! * [`metrics`] — accuracy and confusion summaries shared by the harness.
//!
//! All linear solvers run over [`Features`], the minimal real-valued
//! access they need (dot, axpy, ‖x‖², label). Binary substrates implement
//! the richer [`BinaryFeatures`] and get [`Features`] by delegation — so
//! the raw shingle datasets and the *virtual* Theorem-2 expansion of a
//! packed signature matrix ([`ExpandedView`]) train exactly as before
//! (same float-op sequence, bit for bit) — while the dense f32 sketches
//! of the VW / projection / bbit+VW schemes plug in through
//! [`DenseView`]. [`SketchView`] dispatches over a
//! [`SketchMatrix`](crate::hashing::sketch::SketchMatrix), so every
//! trainer consumes any hashing scheme's output.

pub mod kernel_svm;
pub mod linear_svm;
pub mod logreg;
pub mod metrics;
pub mod sgd;

pub use sgd::{SgdCore, SgdLoss};

use crate::data::sparse::SparseBinaryDataset;
use crate::hashing::bbit::BbitSignatureMatrix;
use crate::hashing::sketch::{F32Matrix, SketchMatrix};

/// Row-iterable binary feature matrix with ±1 labels.
///
/// `for_each_index` visits the positions of the 1-entries of row `i` (in
/// any order); `row_nnz` is the number of such entries (= ‖x_i‖²).
pub trait BinaryFeatures: Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    fn label(&self, i: usize) -> f32;
    fn row_nnz(&self, i: usize) -> usize;
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, f: F);

    /// w·x_i over a dense weight vector.
    fn dot(&self, i: usize, w: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        self.for_each_index(i, |idx| acc += w[idx] as f64);
        acc
    }

    /// w += scale · x_i.
    fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
        self.for_each_index(i, |idx| w[idx] += scale as f32);
    }
}

/// The real-valued feature access the linear solvers actually need.
/// Binary substrates ([`SparseBinaryDataset`], [`ExpandedView`]) get it
/// through `binary_features_impl!` delegating impls that run the
/// *identical* float-op sequence the solvers ran before the trait split —
/// preserving bit-for-bit training results — while dense f32 sketch rows
/// implement it directly ([`DenseView`]). (A blanket impl over
/// [`BinaryFeatures`] would conflict with the direct dense impls under
/// Rust's coherence rules, hence the macro.)
pub trait Features: Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    fn label(&self, i: usize) -> f32;

    /// ‖x_i‖² — the DCD diagonal Q_ii (= nnz for binary rows).
    fn row_norm_sq(&self, i: usize) -> f64;

    /// w·x_i over a dense weight vector.
    fn dot(&self, i: usize, w: &[f32]) -> f64;

    /// w += scale · x_i.
    fn axpy(&self, i: usize, scale: f64, w: &mut [f32]);
}

/// Implement [`Features`] for a [`BinaryFeatures`] type by delegation —
/// the same default-method float ops, so training results cannot drift.
macro_rules! binary_features_impl {
    ($ty:ty) => {
        impl Features for $ty {
            fn n(&self) -> usize {
                BinaryFeatures::n(self)
            }
            fn dim(&self) -> usize {
                BinaryFeatures::dim(self)
            }
            fn label(&self, i: usize) -> f32 {
                BinaryFeatures::label(self, i)
            }
            fn row_norm_sq(&self, i: usize) -> f64 {
                self.row_nnz(i) as f64
            }
            fn dot(&self, i: usize, w: &[f32]) -> f64 {
                BinaryFeatures::dot(self, i, w)
            }
            fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
                BinaryFeatures::axpy(self, i, scale, w)
            }
        }
    };
}

binary_features_impl!(SparseBinaryDataset);
binary_features_impl!(ExpandedView<'_>);

impl BinaryFeatures for SparseBinaryDataset {
    fn n(&self) -> usize {
        SparseBinaryDataset::n(self)
    }
    fn dim(&self) -> usize {
        SparseBinaryDataset::dim(self) as usize
    }
    fn label(&self, i: usize) -> f32 {
        SparseBinaryDataset::label(self, i)
    }
    fn row_nnz(&self, i: usize) -> usize {
        self.row(i).len()
    }
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, mut f: F) {
        for &idx in self.row(i) {
            f(idx as usize);
        }
    }
}

/// The virtual Theorem-2 expansion of a b-bit signature matrix: row i has
/// ones exactly at `{ j·2^b + sig[i,j] : j < k }` (paper §4). Unpacking is
/// done on the fly; nothing of size n × 2^b·k is ever materialized.
pub struct ExpandedView<'a> {
    m: &'a BbitSignatureMatrix,
}

impl<'a> ExpandedView<'a> {
    pub fn new(m: &'a BbitSignatureMatrix) -> Self {
        Self { m }
    }

    pub fn signatures(&self) -> &BbitSignatureMatrix {
        self.m
    }
}

impl BinaryFeatures for ExpandedView<'_> {
    fn n(&self) -> usize {
        self.m.n()
    }
    fn dim(&self) -> usize {
        self.m.k() << self.m.b()
    }
    fn label(&self, i: usize) -> f32 {
        self.m.label(i)
    }
    fn row_nnz(&self, _i: usize) -> usize {
        self.m.k() // exactly k ones per expanded row
    }
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, mut f: F) {
        let width = 1usize << self.m.b();
        // Delegates to the packed store's slice fast path (§Perf): the DCD
        // solvers call this twice per coordinate update.
        self.m.for_each_value(i, |j, v| f(j * width + v as usize));
    }
}

/// Dense f32 sketch rows as trainable features: row i of an [`F32Matrix`]
/// *is* the feature vector (the VW / projection samples are already the
/// k-dim representation — no expansion involved).
pub struct DenseView<'a> {
    m: &'a F32Matrix,
}

impl<'a> DenseView<'a> {
    pub fn new(m: &'a F32Matrix) -> Self {
        Self { m }
    }

    pub fn matrix(&self) -> &F32Matrix {
        self.m
    }
}

impl Features for DenseView<'_> {
    fn n(&self) -> usize {
        self.m.n()
    }
    fn dim(&self) -> usize {
        self.m.k()
    }
    fn label(&self, i: usize) -> f32 {
        self.m.label(i)
    }
    fn row_norm_sq(&self, i: usize) -> f64 {
        self.m
            .row(i)
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum()
    }
    fn dot(&self, i: usize, w: &[f32]) -> f64 {
        self.m
            .row(i)
            .iter()
            .zip(w)
            .map(|(&v, &wj)| v as f64 * wj as f64)
            .sum()
    }
    fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
        for (wj, &v) in w.iter_mut().zip(self.m.row(i)) {
            *wj += (scale * v as f64) as f32;
        }
    }
}

/// Trainable view over any [`SketchMatrix`]: the virtual Theorem-2
/// expansion for packed signatures, the rows themselves for dense samples.
/// This is what makes every linear backend consume any hashing scheme.
pub enum SketchView<'a> {
    Expanded(ExpandedView<'a>),
    Dense(DenseView<'a>),
}

impl<'a> SketchView<'a> {
    pub fn new(m: &'a SketchMatrix) -> Self {
        match m {
            SketchMatrix::Bbit(b) => Self::Expanded(ExpandedView::new(b)),
            SketchMatrix::Dense(d) => Self::Dense(DenseView::new(d)),
        }
    }
}

impl Features for SketchView<'_> {
    fn n(&self) -> usize {
        match self {
            Self::Expanded(v) => Features::n(v),
            Self::Dense(v) => Features::n(v),
        }
    }
    fn dim(&self) -> usize {
        match self {
            Self::Expanded(v) => Features::dim(v),
            Self::Dense(v) => Features::dim(v),
        }
    }
    fn label(&self, i: usize) -> f32 {
        match self {
            Self::Expanded(v) => Features::label(v, i),
            Self::Dense(v) => Features::label(v, i),
        }
    }
    fn row_norm_sq(&self, i: usize) -> f64 {
        match self {
            Self::Expanded(v) => Features::row_norm_sq(v, i),
            Self::Dense(v) => Features::row_norm_sq(v, i),
        }
    }
    fn dot(&self, i: usize, w: &[f32]) -> f64 {
        match self {
            Self::Expanded(v) => Features::dot(v, i, w),
            Self::Dense(v) => Features::dot(v, i, w),
        }
    }
    fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
        match self {
            Self::Expanded(v) => Features::axpy(v, i, scale, w),
            Self::Dense(v) => Features::axpy(v, i, scale, w),
        }
    }
}

/// A trained linear model (dense weights over the feature dimension).
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
    /// Solver epochs/iterations actually used.
    pub iters: usize,
    /// Final objective value (primal for SGD, dual-derived for CD solvers).
    pub objective: f64,
}

impl LinearModel {
    /// Decision value w·x for a feature row.
    pub fn score<Ft: Features>(&self, feats: &Ft, i: usize) -> f64 {
        feats.dot(i, &self.w)
    }

    /// Predicted label ∈ {−1, +1}.
    pub fn predict<Ft: Features>(&self, feats: &Ft, i: usize) -> f32 {
        if self.score(feats, i) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Accuracy over a feature set.
    pub fn accuracy<Ft: Features>(&self, feats: &Ft) -> f64 {
        if feats.n() == 0 {
            return 0.0;
        }
        let correct = (0..feats.n())
            .filter(|&i| self.predict(feats, i) == feats.label(i))
            .count();
        correct as f64 / feats.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::SparseBinaryVec;

    #[test]
    fn expanded_view_indices_match_materialized_expansion() {
        let mut m = BbitSignatureMatrix::new(3, 2);
        m.push_row(&[1, 0, 3], 1.0);
        m.push_row(&[2, 2, 2], -1.0);
        let view = ExpandedView::new(&m);
        assert_eq!(BinaryFeatures::n(&view), 2);
        assert_eq!(BinaryFeatures::dim(&view), 12);
        assert_eq!(view.row_nnz(0), 3);
        let mut got = Vec::new();
        view.for_each_index(0, |i| got.push(i));
        assert_eq!(got, vec![1, 4, 11]);
        let expanded = crate::hashing::expand::expand_matrix(&m);
        let mut got1 = Vec::new();
        view.for_each_index(1, |i| got1.push(i as u64));
        assert_eq!(got1, expanded.row(1));
    }

    #[test]
    fn dot_and_axpy_are_consistent() {
        let mut ds = SparseBinaryDataset::new(8);
        ds.push(SparseBinaryVec::from_indices(vec![1, 3, 5]), 1.0);
        let mut w = vec![0.0f32; 8];
        BinaryFeatures::axpy(&ds, 0, 2.0, &mut w);
        assert_eq!(w[1], 2.0);
        assert_eq!(w[3], 2.0);
        assert_eq!(w[0], 0.0);
        assert!((BinaryFeatures::dot(&ds, 0, &w) - 6.0).abs() < 1e-9);
        // The blanket Features impl is the same ops, bit for bit.
        assert_eq!(
            Features::dot(&ds, 0, &w).to_bits(),
            BinaryFeatures::dot(&ds, 0, &w).to_bits()
        );
        assert_eq!(Features::row_norm_sq(&ds, 0), 3.0);
    }

    #[test]
    fn dense_view_dot_axpy_and_norm() {
        let mut m = F32Matrix::new(3);
        m.push_row(&[1.0, -2.0, 0.0], 1.0);
        m.push_row(&[0.5, 0.5, 2.0], -1.0);
        let v = DenseView::new(&m);
        assert_eq!(Features::n(&v), 2);
        assert_eq!(Features::dim(&v), 3);
        assert_eq!(Features::label(&v, 1), -1.0);
        assert!((Features::row_norm_sq(&v, 0) - 5.0).abs() < 1e-12);
        let mut w = vec![0.0f32; 3];
        Features::axpy(&v, 0, 2.0, &mut w);
        assert_eq!(w, vec![2.0, -4.0, 0.0]);
        assert!((Features::dot(&v, 0, &w) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sketch_view_dispatches_to_both_variants() {
        // Packed variant: same values as the direct ExpandedView.
        let mut b = BbitSignatureMatrix::new(3, 2);
        b.push_row(&[1, 0, 3], 1.0);
        let sk = SketchMatrix::Bbit(b.clone());
        let view = SketchView::new(&sk);
        let direct = ExpandedView::new(&b);
        assert_eq!(Features::n(&view), 1);
        assert_eq!(Features::dim(&view), 12);
        let w: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(
            Features::dot(&view, 0, &w).to_bits(),
            Features::dot(&direct, 0, &w).to_bits(),
            "packed dispatch must be the identical op sequence"
        );
        // Dense variant.
        let mut d = F32Matrix::new(2);
        d.push_row(&[2.0, -1.0], -1.0);
        let skd = SketchMatrix::Dense(d);
        let vd = SketchView::new(&skd);
        assert_eq!(Features::dim(&vd), 2);
        assert_eq!(Features::dot(&vd, 0, &[1.0, 1.0]), 1.0);
        assert_eq!(Features::row_norm_sq(&vd, 0), 5.0);
    }

    #[test]
    fn linear_model_scores_and_predicts() {
        let mut ds = SparseBinaryDataset::new(4);
        ds.push(SparseBinaryVec::from_indices(vec![0]), 1.0);
        ds.push(SparseBinaryVec::from_indices(vec![1]), -1.0);
        let m = LinearModel {
            w: vec![1.0, -1.0, 0.0, 0.0],
            iters: 0,
            objective: 0.0,
        };
        assert_eq!(m.predict(&ds, 0), 1.0);
        assert_eq!(m.predict(&ds, 1), -1.0);
        assert_eq!(m.accuracy(&ds), 1.0);
    }
}
