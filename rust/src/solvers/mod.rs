//! Learning algorithms (paper §4–§5): the training substrate the paper runs
//! on top of LIBLINEAR/LIBSVM, re-implemented from scratch.
//!
//! * [`linear_svm`] — dual coordinate descent for L1-/L2-loss linear SVM
//!   (Hsieh et al., ICML 2008 — LIBLINEAR's `-s 3`/`-s 1`).
//! * [`logreg`] — dual coordinate descent for L2-regularized logistic
//!   regression (Yu, Huang, Lin — LIBLINEAR's `-s 7`).
//! * [`sgd`] — Pegasos-style stochastic subgradient SVM (the paper cites
//!   Pegasos/Bottou SGD as the representative solver family).
//! * [`kernel_svm`] — SMO-style dual solver over an arbitrary kernel with
//!   a row cache; used with the resemblance / b-bit estimated kernels for
//!   the paper's §5.1 nonlinear experiments.
//! * [`metrics`] — accuracy and confusion summaries shared by the harness.
//!
//! All linear solvers run over [`BinaryFeatures`], a zero-copy abstraction
//! that serves both raw shingle datasets and the *virtual* Theorem-2
//! expansion of a packed signature matrix ([`ExpandedView`]) — the 2^b·k
//! one-hot features are never materialized during training.

pub mod kernel_svm;
pub mod linear_svm;
pub mod logreg;
pub mod metrics;
pub mod sgd;

use crate::data::sparse::SparseBinaryDataset;
use crate::hashing::bbit::BbitSignatureMatrix;

/// Row-iterable binary feature matrix with ±1 labels.
///
/// `for_each_index` visits the positions of the 1-entries of row `i` (in
/// any order); `row_nnz` is the number of such entries (= ‖x_i‖²).
pub trait BinaryFeatures: Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    fn label(&self, i: usize) -> f32;
    fn row_nnz(&self, i: usize) -> usize;
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, f: F);

    /// w·x_i over a dense weight vector.
    fn dot(&self, i: usize, w: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        self.for_each_index(i, |idx| acc += w[idx] as f64);
        acc
    }

    /// w += scale · x_i.
    fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
        self.for_each_index(i, |idx| w[idx] += scale as f32);
    }
}

impl BinaryFeatures for SparseBinaryDataset {
    fn n(&self) -> usize {
        SparseBinaryDataset::n(self)
    }
    fn dim(&self) -> usize {
        SparseBinaryDataset::dim(self) as usize
    }
    fn label(&self, i: usize) -> f32 {
        SparseBinaryDataset::label(self, i)
    }
    fn row_nnz(&self, i: usize) -> usize {
        self.row(i).len()
    }
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, mut f: F) {
        for &idx in self.row(i) {
            f(idx as usize);
        }
    }
}

/// The virtual Theorem-2 expansion of a b-bit signature matrix: row i has
/// ones exactly at `{ j·2^b + sig[i,j] : j < k }` (paper §4). Unpacking is
/// done on the fly; nothing of size n × 2^b·k is ever materialized.
pub struct ExpandedView<'a> {
    m: &'a BbitSignatureMatrix,
}

impl<'a> ExpandedView<'a> {
    pub fn new(m: &'a BbitSignatureMatrix) -> Self {
        Self { m }
    }

    pub fn signatures(&self) -> &BbitSignatureMatrix {
        self.m
    }
}

impl BinaryFeatures for ExpandedView<'_> {
    fn n(&self) -> usize {
        self.m.n()
    }
    fn dim(&self) -> usize {
        self.m.k() << self.m.b()
    }
    fn label(&self, i: usize) -> f32 {
        self.m.label(i)
    }
    fn row_nnz(&self, _i: usize) -> usize {
        self.m.k() // exactly k ones per expanded row
    }
    fn for_each_index<F: FnMut(usize)>(&self, i: usize, mut f: F) {
        let width = 1usize << self.m.b();
        // Delegates to the packed store's slice fast path (§Perf): the DCD
        // solvers call this twice per coordinate update.
        self.m.for_each_value(i, |j, v| f(j * width + v as usize));
    }
}

/// A trained linear model (dense weights over the feature dimension).
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
    /// Solver epochs/iterations actually used.
    pub iters: usize,
    /// Final objective value (primal for SGD, dual-derived for CD solvers).
    pub objective: f64,
}

impl LinearModel {
    /// Decision value w·x for a feature row.
    pub fn score<Ft: BinaryFeatures>(&self, feats: &Ft, i: usize) -> f64 {
        feats.dot(i, &self.w)
    }

    /// Predicted label ∈ {−1, +1}.
    pub fn predict<Ft: BinaryFeatures>(&self, feats: &Ft, i: usize) -> f32 {
        if self.score(feats, i) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Accuracy over a feature set.
    pub fn accuracy<Ft: BinaryFeatures>(&self, feats: &Ft) -> f64 {
        if feats.n() == 0 {
            return 0.0;
        }
        let correct = (0..feats.n())
            .filter(|&i| self.predict(feats, i) == feats.label(i))
            .count();
        correct as f64 / feats.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::SparseBinaryVec;

    #[test]
    fn expanded_view_indices_match_materialized_expansion() {
        let mut m = BbitSignatureMatrix::new(3, 2);
        m.push_row(&[1, 0, 3], 1.0);
        m.push_row(&[2, 2, 2], -1.0);
        let view = ExpandedView::new(&m);
        assert_eq!(view.n(), 2);
        assert_eq!(view.dim(), 12);
        assert_eq!(view.row_nnz(0), 3);
        let mut got = Vec::new();
        view.for_each_index(0, |i| got.push(i));
        assert_eq!(got, vec![1, 4, 11]);
        let expanded = crate::hashing::expand::expand_matrix(&m);
        let mut got1 = Vec::new();
        view.for_each_index(1, |i| got1.push(i as u64));
        assert_eq!(got1, expanded.row(1));
    }

    #[test]
    fn dot_and_axpy_are_consistent() {
        let mut ds = SparseBinaryDataset::new(8);
        ds.push(SparseBinaryVec::from_indices(vec![1, 3, 5]), 1.0);
        let mut w = vec![0.0f32; 8];
        ds.axpy(0, 2.0, &mut w);
        assert_eq!(w[1], 2.0);
        assert_eq!(w[3], 2.0);
        assert_eq!(w[0], 0.0);
        assert!((ds.dot(0, &w) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn linear_model_scores_and_predicts() {
        let mut ds = SparseBinaryDataset::new(4);
        ds.push(SparseBinaryVec::from_indices(vec![0]), 1.0);
        ds.push(SparseBinaryVec::from_indices(vec![1]), -1.0);
        let m = LinearModel {
            w: vec![1.0, -1.0, 0.0, 0.0],
            iters: 0,
            objective: 0.0,
        };
        assert_eq!(m.predict(&ds, 0), 1.0);
        assert_eq!(m.predict(&ds, 1), -1.0);
        assert_eq!(m.accuracy(&ds), 1.0);
    }
}
