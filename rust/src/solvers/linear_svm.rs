//! Linear SVM via dual coordinate descent (Hsieh et al., ICML 2008) — the
//! algorithm inside LIBLINEAR that the paper's §5.2 experiments run.
//!
//! Solves the paper's eq. (9):
//!
//!   min_w  ½‖w‖² + C Σ_i loss(1 − y_i·w·x_i)
//!
//! with `loss` either the L1 hinge (max(0, ·)) or the L2 squared hinge.
//! The dual has box constraints 0 ≤ α_i ≤ U (U = C for L1, ∞ for L2) and a
//! diagonal regularizer D_ii (0 for L1, 1/(2C) for L2); each coordinate
//! update is O(nnz(x_i)) through the maintained primal vector
//! w = Σ α_i y_i x_i.

use super::{Features, LinearModel};
use crate::rng::Xoshiro256;

/// Which SVM loss to optimize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmLoss {
    /// Hinge loss (LIBLINEAR `-s 3`).
    L1,
    /// Squared hinge loss (LIBLINEAR `-s 1`).
    L2,
}

/// Solver options.
#[derive(Clone, Debug)]
pub struct SvmOptions {
    pub c: f64,
    pub loss: SvmLoss,
    /// Maximum outer epochs over the data.
    pub max_iter: usize,
    /// Stop when the maximal projected gradient over an epoch < tol.
    pub tol: f64,
    pub seed: u64,
}

impl Default for SvmOptions {
    fn default() -> Self {
        Self {
            c: 1.0,
            loss: SvmLoss::L2,
            max_iter: 200,
            tol: 1e-3,
            seed: 1,
        }
    }
}

/// Train a linear SVM by dual coordinate descent. Generic over
/// [`Features`], so it consumes binary substrates (raw shingles, the
/// virtual Theorem-2 expansion) and the dense f32 samples of the VW /
/// projection schemes alike.
pub fn train_svm<Ft: Features>(feats: &Ft, opt: &SvmOptions) -> LinearModel {
    let n = feats.n();
    let dim = feats.dim();
    assert!(n > 0, "empty training set");
    let (diag, upper) = match opt.loss {
        SvmLoss::L1 => (0.0, opt.c),
        SvmLoss::L2 => (0.5 / opt.c, f64::INFINITY),
    };

    let mut w = vec![0.0f32; dim];
    let mut alpha = vec![0.0f64; n];
    // Q_ii = x_i·x_i + D_ii (= nnz(i) + D_ii on binary data).
    let qd: Vec<f64> = (0..n).map(|i| feats.row_norm_sq(i) + diag).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256::seed_from_u64(opt.seed);

    let mut epochs = 0;
    for epoch in 0..opt.max_iter {
        epochs = epoch + 1;
        rng.shuffle(&mut order);
        let mut max_pg: f64 = 0.0;
        for &i in &order {
            if qd[i] <= diag {
                continue; // empty row: nothing to update
            }
            let y = feats.label(i) as f64;
            // G = y·w·x_i − 1 + D_ii·α_i
            let g = y * feats.dot(i, &w) - 1.0 + diag * alpha[i];
            // Projected gradient under 0 ≤ α ≤ U.
            let pg = if alpha[i] <= 0.0 {
                g.min(0.0)
            } else if alpha[i] >= upper {
                g.max(0.0)
            } else {
                g
            };
            max_pg = max_pg.max(pg.abs());
            if pg.abs() > 1e-14 {
                let old = alpha[i];
                alpha[i] = (old - g / qd[i]).clamp(0.0, upper);
                let delta = (alpha[i] - old) * y;
                if delta != 0.0 {
                    feats.axpy(i, delta, &mut w);
                }
            }
        }
        if max_pg < opt.tol {
            break;
        }
    }

    // Primal objective for reporting.
    let objective = primal_objective(feats, &w, opt);
    LinearModel {
        w,
        iters: epochs,
        objective,
    }
}

/// Dual coordinate descent over *real-valued* sparse features — the same
/// algorithm as [`train_svm`] but for the VW / random-projection baselines
/// whose hashed samples are signed sums (paper §7's comparison needs to
/// train on them).
pub fn train_svm_real(
    data: &crate::data::real::SparseRealDataset,
    opt: &SvmOptions,
) -> LinearModel {
    let n = data.n();
    assert!(n > 0, "empty training set");
    let (diag, upper) = match opt.loss {
        SvmLoss::L1 => (0.0, opt.c),
        SvmLoss::L2 => (0.5 / opt.c, f64::INFINITY),
    };
    let mut w = vec![0.0f32; data.dim()];
    let mut alpha = vec![0.0f64; n];
    let qd: Vec<f64> = (0..n).map(|i| data.row_norm_sq(i) + diag).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256::seed_from_u64(opt.seed);

    let mut epochs = 0;
    for epoch in 0..opt.max_iter {
        epochs = epoch + 1;
        rng.shuffle(&mut order);
        let mut max_pg: f64 = 0.0;
        for &i in &order {
            if qd[i] <= diag {
                continue;
            }
            let y = data.label(i) as f64;
            let g = y * data.dot(i, &w) - 1.0 + diag * alpha[i];
            let pg = if alpha[i] <= 0.0 {
                g.min(0.0)
            } else if alpha[i] >= upper {
                g.max(0.0)
            } else {
                g
            };
            max_pg = max_pg.max(pg.abs());
            if pg.abs() > 1e-14 {
                let old = alpha[i];
                alpha[i] = (old - g / qd[i]).clamp(0.0, upper);
                let delta = (alpha[i] - old) * y;
                if delta != 0.0 {
                    data.axpy(i, delta, &mut w);
                }
            }
        }
        if max_pg < opt.tol {
            break;
        }
    }
    // Primal objective (hinge over real features).
    let reg: f64 = 0.5 * w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
    let mut loss = 0.0;
    for i in 0..n {
        let m = 1.0 - data.label(i) as f64 * data.dot(i, &w);
        if m > 0.0 {
            loss += match opt.loss {
                SvmLoss::L1 => m,
                SvmLoss::L2 => m * m,
            };
        }
    }
    LinearModel {
        w,
        iters: epochs,
        objective: reg + opt.c * loss,
    }
}

/// Accuracy of a model over real-valued features.
pub fn accuracy_real(model: &LinearModel, data: &crate::data::real::SparseRealDataset) -> f64 {
    if data.n() == 0 {
        return 0.0;
    }
    let correct = (0..data.n())
        .filter(|&i| {
            let s = data.dot(i, &model.w);
            (s >= 0.0) == (data.label(i) > 0.0)
        })
        .count();
    correct as f64 / data.n() as f64
}

/// Primal objective value of eq. (9) at w.
pub fn primal_objective<Ft: Features>(feats: &Ft, w: &[f32], opt: &SvmOptions) -> f64 {
    let reg: f64 = 0.5 * w.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
    let mut loss = 0.0;
    for i in 0..feats.n() {
        let m = 1.0 - feats.label(i) as f64 * feats.dot(i, w);
        if m > 0.0 {
            loss += match opt.loss {
                SvmLoss::L1 => m,
                SvmLoss::L2 => m * m,
            };
        }
    }
    reg + opt.c * loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
    use crate::rng::Xoshiro256;

    /// Linearly separable toy data: positive examples contain feature 0,
    /// negative contain feature 1; shared noise features elsewhere.
    fn toy(n: usize, dim: u64, seed: u64) -> SparseBinaryDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ds = SparseBinaryDataset::new(dim);
        for i in 0..n {
            let pos = i % 2 == 0;
            let mut idx = vec![if pos { 0u64 } else { 1u64 }];
            for _ in 0..5 {
                idx.push(2 + rng.gen_range(dim - 2));
            }
            ds.push(
                SparseBinaryVec::from_indices(idx),
                if pos { 1.0 } else { -1.0 },
            );
        }
        ds
    }

    #[test]
    fn separable_data_reaches_full_accuracy() {
        let ds = toy(200, 100, 3);
        for loss in [SvmLoss::L1, SvmLoss::L2] {
            let model = train_svm(
                &ds,
                &SvmOptions {
                    c: 1.0,
                    loss,
                    ..Default::default()
                },
            );
            assert_eq!(model.accuracy(&ds), 1.0, "{loss:?}");
        }
    }

    #[test]
    fn objective_decreases_with_more_iterations() {
        let ds = toy(300, 200, 7);
        let o1 = train_svm(
            &ds,
            &SvmOptions {
                max_iter: 1,
                tol: 0.0,
                ..Default::default()
            },
        )
        .objective;
        let o50 = train_svm(
            &ds,
            &SvmOptions {
                max_iter: 50,
                tol: 0.0,
                ..Default::default()
            },
        )
        .objective;
        assert!(o50 <= o1 + 1e-9, "{o50} !<= {o1}");
    }

    #[test]
    fn l1_alpha_box_respected_via_weight_norm() {
        // With tiny C the model barely moves: ‖w‖ is bounded by C Σ‖x_i‖.
        let ds = toy(100, 50, 1);
        let model = train_svm(
            &ds,
            &SvmOptions {
                c: 1e-4,
                loss: SvmLoss::L1,
                ..Default::default()
            },
        );
        let norm: f64 = model.w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(norm < 0.2, "‖w‖ = {norm}");
    }

    #[test]
    fn dcd_matches_reference_on_tiny_problem() {
        // 2 points, 2 features, analytically checkable: x1 = e0 (y=+1),
        // x2 = e1 (y=−1). By symmetry w* = (c, −c) with c = min(C, 1/?):
        // dual: α_i = clamp(1/(Q_ii) adjusted) — for L1 loss the optimum
        // is α1 = α2 = min(C, 1) (Q_ii = 1, margins independent), giving
        // w = (α1, −α2).
        let mut ds = SparseBinaryDataset::new(2);
        ds.push(SparseBinaryVec::from_indices(vec![0]), 1.0);
        ds.push(SparseBinaryVec::from_indices(vec![1]), -1.0);
        for c in [0.25, 0.5, 2.0] {
            let model = train_svm(
                &ds,
                &SvmOptions {
                    c,
                    loss: SvmLoss::L1,
                    max_iter: 500,
                    tol: 1e-9,
                    ..Default::default()
                },
            );
            let expect = c.min(1.0) as f32;
            assert!(
                (model.w[0] - expect).abs() < 1e-4 && (model.w[1] + expect).abs() < 1e-4,
                "C={c}: w = {:?}",
                model.w
            );
        }
    }

    #[test]
    fn works_on_expanded_view() {
        // Train on the virtual expansion of a signature matrix where class
        // is encoded in the first signature slot.
        use crate::hashing::bbit::BbitSignatureMatrix;
        let mut m = BbitSignatureMatrix::new(4, 4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        for i in 0..100 {
            let pos = i % 2 == 0;
            let row = [
                if pos { 1u16 } else { 2u16 },
                (rng.next_u32() & 15) as u16,
                (rng.next_u32() & 15) as u16,
                (rng.next_u32() & 15) as u16,
            ];
            m.push_row(&row, if pos { 1.0 } else { -1.0 });
        }
        let view = super::super::ExpandedView::new(&m);
        let model = train_svm(&view, &SvmOptions::default());
        assert!(model.accuracy(&view) > 0.99);
    }

    #[test]
    fn real_dcd_matches_binary_dcd_on_binary_input() {
        // Feeding 0/1 values through the real-valued path must reproduce
        // the binary path exactly (same seed ⇒ same visit order).
        let ds = toy(120, 80, 21);
        let mut real = crate::data::real::SparseRealDataset::new(80);
        for i in 0..ds.n() {
            let row: Vec<(u32, f32)> = ds.row(i).iter().map(|&j| (j as u32, 1.0)).collect();
            real.push(&row, ds.label(i));
        }
        let opt = SvmOptions::default();
        let mb = train_svm(&ds, &opt);
        let mr = train_svm_real(&real, &opt);
        for (a, b) in mb.w.iter().zip(&mr.w) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert!((accuracy_real(&mr, &real) - mb.accuracy(&ds)).abs() < 1e-12);
    }

    #[test]
    fn real_dcd_learns_signed_features() {
        // Signed VW-like features: class sign carried by a real feature.
        let mut real = crate::data::real::SparseRealDataset::new(16);
        let mut rng = Xoshiro256::seed_from_u64(4);
        for i in 0..200 {
            let pos = i % 2 == 0;
            let noise = (rng.gen_range(14) + 2) as u32;
            let row = [
                (0u32, if pos { 1.5f32 } else { -1.5 }),
                (noise, rng.gen_f32() - 0.5),
            ];
            let mut row = row.to_vec();
            row.sort_by_key(|&(j, _)| j);
            row.dedup_by_key(|p| p.0);
            real.push(&row, if pos { 1.0 } else { -1.0 });
        }
        let model = train_svm_real(&real, &SvmOptions::default());
        assert!(accuracy_real(&model, &real) > 0.95);
    }

    #[test]
    fn handles_empty_rows_gracefully() {
        let mut ds = SparseBinaryDataset::new(4);
        ds.push(SparseBinaryVec::from_indices(vec![0]), 1.0);
        ds.push(SparseBinaryVec::from_indices(vec![]), -1.0);
        ds.push(SparseBinaryVec::from_indices(vec![1]), -1.0);
        let model = train_svm(&ds, &SvmOptions::default());
        assert!(model.w.iter().all(|x| x.is_finite()));
    }
}
