//! Nonlinear (kernel) SVM via SMO — the paper's §5.1 experiment substrate.
//!
//! The paper trained LIBSVM with a custom *resemblance kernel* and found it
//! infeasible on the raw data (>1 week), but tractable on the b-bit
//! estimated kernel. We implement the dual L1-SVM
//!
//!   max_α Σα_i − ½ ΣΣ α_i α_j y_i y_j K(i,j),   0 ≤ α_i ≤ C
//!
//! (no bias term, matching our linear solvers and the paper's LIBLINEAR
//! usage) with greedy maximal-violating-coordinate updates and an LRU row
//! cache, so the Gram matrix is computed lazily — exactly the regime where
//! estimated kernels from small signatures beat exact resemblance on
//! massive raw data.

use std::collections::HashMap;

/// A kernel function over example indices.
pub trait Kernel: Sync {
    fn n(&self) -> usize;
    fn label(&self, i: usize) -> f32;
    fn eval(&self, i: usize, j: usize) -> f64;

    /// Fill `out` with the full Gram row K(i, ·). The default evaluates
    /// pointwise; kernels with a batched path override it — [`BbitKernel`]
    /// fills the row with the packed store's SWAR Gram-row primitive
    /// (`match_count_row_div_into`), which is what makes the lazy
    /// row-cache fills cheap (§5.1).
    fn fill_row(&self, i: usize, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(self.n());
        for j in 0..self.n() {
            out.push(self.eval(i, j));
        }
    }

    /// Fill one Gram row per index of `is` into `out` (cleared first;
    /// `out[r]` is K(is[r], ·)). The default loops [`Kernel::fill_row`];
    /// kernels with a blocked path override it — [`BbitKernel`] computes
    /// all requested rows in one parallel SWAR tile
    /// (`match_count_block_par`), which is what makes the SMO row-cache's
    /// multi-row prefetch pay on cache misses. Values must be identical to
    /// the pointwise path (the solver's results may not depend on which
    /// fill path ran).
    fn fill_rows(&self, is: &[usize], out: &mut Vec<Vec<f64>>) {
        out.clear();
        for &i in is {
            let mut row = Vec::new();
            self.fill_row(i, &mut row);
            out.push(row);
        }
    }
}

/// Resemblance kernel over raw sparse sets: K(i,j) = R(S_i, S_j) (PD by
/// Theorem 2).
pub struct ResemblanceKernel<'a> {
    pub data: &'a crate::data::sparse::SparseBinaryDataset,
}

impl Kernel for ResemblanceKernel<'_> {
    fn n(&self) -> usize {
        self.data.n()
    }
    fn label(&self, i: usize) -> f32 {
        self.data.label(i)
    }
    fn eval(&self, i: usize, j: usize) -> f64 {
        self.data.row_vec(i).resemblance(&self.data.row_vec(j))
    }
}

/// The b-bit estimated kernel: K(i,j) = P̂_b(i,j) = match_count/k — the
/// normalized Theorem-2 Gram matrix (PD as an average of PD matrices).
/// This is what made §5.1 tractable.
pub struct BbitKernel<'a> {
    pub sigs: &'a crate::hashing::bbit::BbitSignatureMatrix,
}

impl Kernel for BbitKernel<'_> {
    fn n(&self) -> usize {
        self.sigs.n()
    }
    fn label(&self, i: usize) -> f32 {
        self.sigs.label(i)
    }
    fn eval(&self, i: usize, j: usize) -> f64 {
        self.sigs.match_count(i, j) as f64 / self.sigs.k() as f64
    }

    // bbml-lint: hot-path
    fn fill_row(&self, i: usize, out: &mut Vec<f64>) {
        self.sigs.match_count_row_div_into(i, self.sigs.k() as f64, out);
    }

    /// Blocked multi-row fill: one `match_count_block_par` tile covers all
    /// requested Gram rows, sharding them across scoped threads so a
    /// row-cache miss prefetch streams the packed store once instead of
    /// once per row. Counts are divided by k exactly like
    /// [`Kernel::eval`], so the values are bit-identical to the pointwise
    /// path.
    fn fill_rows(&self, is: &[usize], out: &mut Vec<Vec<f64>>) {
        out.clear();
        let n = self.sigs.n();
        if is.is_empty() || n == 0 {
            return;
        }
        let k = self.sigs.k() as f64;
        let all: Vec<usize> = (0..n).collect();
        // match_count_block_par goes serial below 2 rows per thread; cap
        // the thread count so small prefetch blocks still fan out.
        let threads = std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1)
            .min(is.len() / 2)
            .max(1);
        let tile = self.sigs.match_count_block_par(is, &all, threads);
        for band in tile.chunks(n) {
            out.push(band.iter().map(|&c| c as f64 / k).collect());
        }
    }
}

/// SMO options.
#[derive(Clone, Debug)]
pub struct KernelSvmOptions {
    pub c: f64,
    /// KKT violation tolerance.
    pub tol: f64,
    /// Hard cap on coordinate updates.
    pub max_updates: usize,
    /// Kernel row cache capacity (rows).
    pub cache_rows: usize,
}

impl Default for KernelSvmOptions {
    fn default() -> Self {
        Self {
            c: 1.0,
            tol: 1e-3,
            max_updates: 200_000,
            cache_rows: 512,
        }
    }
}

/// Kernel row cache with **true LRU eviction**: every access stamps its
/// entry with a monotone tick, and eviction removes the entry with the
/// smallest stamp (an O(len) argmin scan — the cap is a few hundred rows
/// and every miss already pays a full Gram-row fill, so the scan is
/// noise). The old arbitrary HashMap-order eviction could throw out the
/// hottest row; SMO's working set (the top KKT violators) re-touches the
/// same rows for long stretches, which is exactly the access pattern LRU
/// keeps. Prefetch block sizing is adaptive — see [`PrefetchPolicy`].
struct RowCache {
    /// row index → (last-access tick, Gram row).
    rows: HashMap<usize, (u64, Vec<f64>)>,
    cap: usize,
    tick: u64,
}

impl RowCache {
    fn new(cap: usize) -> Self {
        Self {
            rows: HashMap::with_capacity(cap),
            cap: cap.max(1),
            tick: 0,
        }
    }

    /// Next access stamp (monotone; u64 cannot realistically wrap).
    #[inline]
    fn stamp(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Evict the least-recently-used entry, never one of `keep` (a
    /// prefetch batch about to be read).
    fn evict_lru(&mut self, keep: &[usize]) {
        let victim = self
            .rows
            .iter()
            .filter(|(i, _)| !keep.contains(*i))
            .min_by_key(|(_, entry)| entry.0)
            .map(|(&i, _)| i);
        if let Some(v) = victim {
            self.rows.remove(&v);
        }
    }

    fn get<K: Kernel>(&mut self, k: &K, i: usize) -> &Vec<f64> {
        let stamp = self.stamp();
        if let Some(entry) = self.rows.get_mut(&i) {
            entry.0 = stamp; // refresh recency on hit
        } else {
            if self.rows.len() >= self.cap {
                self.evict_lru(&[]);
            }
            let mut row = Vec::new();
            k.fill_row(i, &mut row);
            self.rows.insert(i, (stamp, row));
        }
        &self.rows[&i].1
    }

    #[inline]
    fn contains(&self, i: usize) -> bool {
        self.rows.contains_key(&i)
    }

    /// Multi-row prefetch: fill every uncached row of `idxs` with ONE
    /// batched kernel call ([`Kernel::fill_rows`] — for [`BbitKernel`] a
    /// parallel SWAR tile) and insert them, evicting LRU entries outside
    /// the *whole* batch as needed — already-cached batch rows get their
    /// stamps refreshed first, so no row about to be read can become the
    /// victim. `scratch` is drained into the cache, so its row allocations
    /// are handed over rather than copied.
    fn prefetch<K: Kernel>(&mut self, k: &K, idxs: &[usize], scratch: &mut Vec<Vec<f64>>) {
        let mut missing = Vec::with_capacity(idxs.len());
        for &i in idxs {
            let stamp = self.stamp();
            if let Some(entry) = self.rows.get_mut(&i) {
                entry.0 = stamp; // batch rows are hot: refresh recency
            } else {
                missing.push(i);
            }
        }
        if missing.is_empty() {
            return;
        }
        k.fill_rows(&missing, scratch);
        for (&i, row) in missing.iter().zip(scratch.drain(..)) {
            if self.rows.len() >= self.cap {
                self.evict_lru(idxs);
            }
            let stamp = self.stamp();
            self.rows.insert(i, (stamp, row));
        }
    }
}

/// A trained kernel SVM model: support-vector coefficients.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// α_i·y_i for every training point (zeros for non-SVs).
    pub coef: Vec<f64>,
    pub updates: usize,
    pub dual_objective: f64,
}

impl KernelModel {
    /// Decision value for an arbitrary kernel column (K(·, x) against all
    /// training points).
    pub fn score_with(&self, kcol: impl Fn(usize) -> f64) -> f64 {
        self.coef
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0.0)
            .map(|(i, &c)| c * kcol(i))
            .sum()
    }

    pub fn n_support(&self) -> usize {
        self.coef.iter().filter(|&&c| c != 0.0).count()
    }
}

/// Smallest prefetch block the adaptive policy may shrink to.
const PREFETCH_MIN: usize = 4;
/// Largest prefetch block the adaptive policy may grow to.
const PREFETCH_MAX: usize = 32;
/// Selections per adaptation window.
const PREFETCH_WINDOW: u32 = 32;
/// Starting block size (the old fixed value).
const PREFETCH_START: usize = 8;

/// Adapts the rows-per-prefetch block to the observed row-cache miss rate
/// (closes the ROADMAP "smarter row-cache policy" item). The reasoning:
/// a high miss rate means the working set outruns the cache, so each miss
/// should haul more of the upcoming violators in one tile sweep; a hitting
/// cache wants small blocks so prefetch inserts stop evicting hot rows.
///
/// The pinned policy: over every [`PREFETCH_WINDOW`] selections, a miss
/// rate ≥ 1/2 doubles the block and ≤ 1/8 halves it, always clamped to
/// `PREFETCH_MIN..=PREFETCH_MAX`; in between, the block holds. Block size
/// only changes *which rows are cached*, never any kernel value, so
/// training results are independent of the policy (tested).
struct PrefetchPolicy {
    block: usize,
    misses: u32,
    seen: u32,
}

impl PrefetchPolicy {
    fn new() -> Self {
        Self {
            block: PREFETCH_START,
            misses: 0,
            seen: 0,
        }
    }

    /// Record one selection's cache outcome; adapt at window boundaries.
    fn record(&mut self, miss: bool) {
        self.seen += 1;
        self.misses += miss as u32;
        if self.seen == PREFETCH_WINDOW {
            if 2 * self.misses >= PREFETCH_WINDOW {
                self.block = (self.block * 2).min(PREFETCH_MAX);
            } else if 8 * self.misses <= PREFETCH_WINDOW {
                self.block = (self.block / 2).max(PREFETCH_MIN);
            }
            self.seen = 0;
            self.misses = 0;
        }
    }

    /// Current block size (rows per miss-path prefetch).
    fn block(&self) -> usize {
        self.block
    }
}

/// Train the dual SVM by greedy coordinate ascent (single-coordinate SMO
/// without bias, valid because we solve the no-offset formulation).
///
/// Row-cache misses are served in blocks: the selection scan already ranks
/// every coordinate by KKT violation, so a miss prefetches the selected
/// row together with the next top violators through [`Kernel::fill_rows`]
/// — for [`BbitKernel`] one parallel SWAR tile (`match_count_block_par`)
/// instead of per-row passes over the packed store. The block size adapts
/// to the observed miss rate ([`PrefetchPolicy`]). The fill path never
/// changes the values (tested), only their cost.
pub fn train_kernel_svm<K: Kernel>(kernel: &K, opt: &KernelSvmOptions) -> KernelModel {
    let n = kernel.n();
    assert!(n > 0);
    let mut alpha = vec![0.0f64; n];
    // Gradient of the dual objective: g_i = 1 − y_i Σ_j α_j y_j K(i,j).
    let mut grad = vec![1.0f64; n];
    let mut cache = RowCache::new(opt.cache_rows);
    let diag: Vec<f64> = (0..n).map(|i| kernel.eval(i, i).max(1e-12)).collect();

    let mut policy = PrefetchPolicy::new();
    // Top violators of the current scan, sorted by violation descending —
    // the prefetch candidates for a cache miss.
    let mut top: Vec<(f64, usize)> = Vec::with_capacity(PREFETCH_MAX + 1);
    let mut block: Vec<usize> = Vec::with_capacity(PREFETCH_MAX);
    let mut scratch: Vec<Vec<f64>> = Vec::new();

    let mut updates = 0usize;
    while updates < opt.max_updates {
        let prefetch = policy.block().min(opt.cache_rows.max(1));
        // Maximal violating coordinate under the box 0 ≤ α ≤ C, tracking
        // the runner-up violators for the miss-path prefetch.
        top.clear();
        for i in 0..n {
            let v = if alpha[i] <= 0.0 {
                grad[i].max(0.0)
            } else if alpha[i] >= opt.c {
                (-grad[i]).max(0.0)
            } else {
                grad[i].abs()
            };
            if v > opt.tol {
                let pos = top.partition_point(|&(tv, _)| tv >= v);
                if pos < prefetch {
                    top.insert(pos, (v, i));
                    top.truncate(prefetch);
                }
            }
        }
        let Some(&(_, i)) = top.first() else { break };
        let old = alpha[i];
        let a_new = (old + grad[i] / diag[i]).clamp(0.0, opt.c);
        let delta = a_new - old;
        if delta == 0.0 {
            break;
        }
        alpha[i] = a_new;
        let yi = kernel.label(i) as f64;
        let miss = !cache.contains(i);
        policy.record(miss);
        if miss {
            // Miss: fetch the whole violator block in one tile sweep.
            block.clear();
            block.extend(top.iter().map(|&(_, j)| j));
            cache.prefetch(kernel, &block, &mut scratch);
        }
        let row = cache.get(kernel, i);
        for j in 0..n {
            let yj = kernel.label(j) as f64;
            grad[j] -= delta * yi * yj * row[j];
        }
        updates += 1;
    }

    // Dual objective Σα − ½ αᵀQα = Σα − ½ Σ α_i (1 − g_i).
    let dual: f64 = alpha
        .iter()
        .zip(&grad)
        .map(|(&a, &g)| a - 0.5 * a * (1.0 - g))
        .sum();
    let coef: Vec<f64> = alpha
        .iter()
        .enumerate()
        .map(|(i, &a)| a * kernel.label(i) as f64)
        .collect();
    KernelModel {
        coef,
        updates,
        dual_objective: dual,
    }
}

/// Accuracy of a kernel model on held-out items given a cross-kernel
/// evaluator `cross(i_test, j_train)`.
pub fn kernel_accuracy<K: Kernel>(
    model: &KernelModel,
    n_test: usize,
    labels: impl Fn(usize) -> f32,
    cross: impl Fn(usize, usize) -> f64,
    _kernel: &K,
) -> f64 {
    if n_test == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in 0..n_test {
        let s = model.score_with(|j| cross(t, j));
        let pred = if s >= 0.0 { 1.0 } else { -1.0 };
        if pred == labels(t) {
            correct += 1;
        }
    }
    correct as f64 / n_test as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
    use crate::hashing::bbit::BbitSignatureMatrix;
    use crate::hashing::minwise::MinwiseHasher;
    use crate::rng::Xoshiro256;

    /// Two clusters of sets: positives share a core block, negatives share
    /// another — resemblance separates them.
    fn cluster_data(n: usize, seed: u64) -> SparseBinaryDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ds = SparseBinaryDataset::new(100_000);
        for i in 0..n {
            let pos = i % 2 == 0;
            let core: Vec<u64> = if pos { (0..40).collect() } else { (50..90).collect() };
            let mut idx = core;
            for _ in 0..20 {
                idx.push(100 + rng.gen_range(99_000));
            }
            ds.push(
                SparseBinaryVec::from_indices(idx),
                if pos { 1.0 } else { -1.0 },
            );
        }
        ds
    }

    #[test]
    fn resemblance_kernel_separates_clusters() {
        let ds = cluster_data(60, 3);
        let kernel = ResemblanceKernel { data: &ds };
        let model = train_kernel_svm(&kernel, &KernelSvmOptions::default());
        let mut correct = 0;
        for i in 0..ds.n() {
            let s = model.score_with(|j| kernel.eval(i, j));
            if (s >= 0.0) == (ds.label(i) > 0.0) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n() as f64 > 0.95, "acc {correct}/60");
        assert!(model.n_support() > 0);
    }

    /// Allocation-free n-row signature build: one shared buffer through
    /// the batched engine (`MinwiseHasher::signature_matrix`), not one
    /// `Vec` per row.
    fn sig_matrix(ds: &SparseBinaryDataset, h: &MinwiseHasher, b: u32) -> BbitSignatureMatrix {
        let rows: Vec<&[u64]> = (0..ds.n()).map(|i| ds.row(i)).collect();
        let labels: Vec<f32> = (0..ds.n()).map(|i| ds.label(i)).collect();
        h.signature_matrix(b, &rows, &labels)
    }

    #[test]
    fn bbit_kernel_matches_resemblance_kernel_accuracy() {
        // §5.1's point: the estimated kernel is as good as the exact one.
        let ds = cluster_data(60, 7);
        let h = MinwiseHasher::new(100_000, 128, 11);
        let sigs = sig_matrix(&ds, &h, 8);
        let kernel = BbitKernel { sigs: &sigs };
        let model = train_kernel_svm(&kernel, &KernelSvmOptions::default());
        let mut correct = 0;
        for i in 0..ds.n() {
            let s = model.score_with(|j| kernel.eval(i, j));
            if (s >= 0.0) == (ds.label(i) > 0.0) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n() as f64 > 0.95, "acc {correct}/60");
    }

    #[test]
    fn bbit_fill_row_matches_pointwise_eval() {
        let ds = cluster_data(24, 21);
        let h = MinwiseHasher::new(100_000, 33, 2); // ragged k·b
        for b in [1u32, 2, 4, 8] {
            let sigs = sig_matrix(&ds, &h, b);
            let kernel = BbitKernel { sigs: &sigs };
            let mut row = Vec::new();
            kernel.fill_row(7, &mut row);
            assert_eq!(row.len(), kernel.n());
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, kernel.eval(7, j), "b={b} j={j}");
            }
        }
    }

    #[test]
    fn bbit_fill_rows_matches_fill_row() {
        // The blocked multi-row fill (parallel SWAR tile) must be value-
        // identical to the per-row path for any index subset, including
        // a single row (serial fallback) and repeated calls (out reuse).
        let ds = cluster_data(30, 33);
        let h = MinwiseHasher::new(100_000, 40, 6);
        for b in [1u32, 4, 8] {
            let sigs = sig_matrix(&ds, &h, b);
            let kernel = BbitKernel { sigs: &sigs };
            let mut rows = Vec::new();
            for is in [vec![5usize], vec![3, 0, 7, 29], (0..30).collect::<Vec<_>>()] {
                kernel.fill_rows(&is, &mut rows);
                assert_eq!(rows.len(), is.len(), "b={b}");
                let mut want = Vec::new();
                for (r, &i) in is.iter().enumerate() {
                    kernel.fill_row(i, &mut want);
                    assert_eq!(rows[r], want, "b={b} block row {r} (i={i})");
                }
            }
        }
    }

    /// A BbitKernel stripped of its batched overrides: eval only, so
    /// fill_row/fill_rows take the pointwise defaults. Training through it
    /// must be bit-identical to the blocked prefetch path.
    struct PointwiseBbit<'a> {
        sigs: &'a BbitSignatureMatrix,
    }

    impl Kernel for PointwiseBbit<'_> {
        fn n(&self) -> usize {
            self.sigs.n()
        }
        fn label(&self, i: usize) -> f32 {
            self.sigs.label(i)
        }
        fn eval(&self, i: usize, j: usize) -> f64 {
            self.sigs.match_count(i, j) as f64 / self.sigs.k() as f64
        }
    }

    #[test]
    fn prefetched_training_is_bit_identical_to_pointwise() {
        let ds = cluster_data(60, 17);
        let h = MinwiseHasher::new(100_000, 64, 3);
        let sigs = sig_matrix(&ds, &h, 8);
        // Tiny cache forces misses (and thus block prefetches) constantly.
        for cache_rows in [2usize, 8, 512] {
            let opt = KernelSvmOptions {
                cache_rows,
                ..Default::default()
            };
            let blocked = train_kernel_svm(&BbitKernel { sigs: &sigs }, &opt);
            let pointwise = train_kernel_svm(&PointwiseBbit { sigs: &sigs }, &opt);
            assert_eq!(blocked.updates, pointwise.updates, "cache={cache_rows}");
            for (a, b) in blocked.coef.iter().zip(&pointwise.coef) {
                assert!((a - b).abs() < 1e-12, "cache={cache_rows}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dual_objective_increases_with_budget() {
        let ds = cluster_data(40, 5);
        let kernel = ResemblanceKernel { data: &ds };
        let small = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                max_updates: 5,
                tol: 0.0,
                ..Default::default()
            },
        );
        let big = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                max_updates: 5000,
                tol: 0.0,
                ..Default::default()
            },
        );
        assert!(big.dual_objective >= small.dual_objective - 1e-9);
    }

    #[test]
    fn alphas_respect_box() {
        let ds = cluster_data(30, 9);
        let kernel = ResemblanceKernel { data: &ds };
        let c = 0.5;
        let model = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                c,
                ..Default::default()
            },
        );
        for (i, &coef) in model.coef.iter().enumerate() {
            let a = coef * kernel.label(i) as f64; // recover α_i ≥ 0
            assert!(a >= -1e-12 && a <= c + 1e-12, "α_{i} = {a}");
        }
    }

    /// Trivial kernel that counts row fills — exercises the cache policy
    /// in isolation.
    struct FillCountingKernel {
        n: usize,
        fills: std::sync::Mutex<Vec<usize>>,
    }

    impl Kernel for FillCountingKernel {
        fn n(&self) -> usize {
            self.n
        }
        fn label(&self, _i: usize) -> f32 {
            1.0
        }
        fn eval(&self, i: usize, j: usize) -> f64 {
            (i * self.n + j) as f64
        }
        fn fill_row(&self, i: usize, out: &mut Vec<f64>) {
            self.fills.lock().unwrap().push(i);
            out.clear();
            for j in 0..self.n {
                out.push(self.eval(i, j));
            }
        }
    }

    #[test]
    fn row_cache_evicts_least_recently_used() {
        let k = FillCountingKernel {
            n: 8,
            fills: std::sync::Mutex::new(Vec::new()),
        };
        let mut cache = RowCache::new(2);
        cache.get(&k, 0); // cache: {0}
        cache.get(&k, 1); // cache: {0, 1}
        cache.get(&k, 0); // refresh 0 — now 1 is the LRU
        cache.get(&k, 2); // evicts 1, NOT 0
        assert!(cache.contains(0), "recently-touched row must survive");
        assert!(!cache.contains(1), "LRU row must be the victim");
        assert!(cache.contains(2));
        // A hit refreshes without refilling.
        let row0 = cache.get(&k, 0).clone();
        assert_eq!(row0[3], k.eval(0, 3));
        assert_eq!(*k.fills.lock().unwrap(), vec![0, 1, 2], "exactly one fill per miss");
    }

    #[test]
    fn row_cache_prefetch_never_evicts_its_own_batch() {
        let k = FillCountingKernel {
            n: 6,
            fills: std::sync::Mutex::new(Vec::new()),
        };
        let mut cache = RowCache::new(3);
        cache.get(&k, 1); // oldest stamp, but part of the upcoming batch
        cache.get(&k, 0); // fresher stamp, NOT in the batch
        let mut scratch = Vec::new();
        // Batch [1, 2, 3]: 1 is already cached (stamp refreshed, fill
        // skipped), 2 and 3 are fetched; inserting 3 overflows the cap.
        // Under unshielded LRU the victim would be 1 (the globally oldest
        // entry) — the shield + refresh make it 0 instead.
        cache.prefetch(&k, &[1, 2, 3], &mut scratch);
        assert!(!cache.contains(0), "non-batch LRU row is the victim");
        assert!(cache.contains(1), "cached batch row must not be evicted");
        assert!(cache.contains(2) && cache.contains(3), "prefetched rows resident");
        assert_eq!(
            *k.fills.lock().unwrap(),
            vec![1, 0, 2, 3],
            "cached batch rows are not refilled"
        );
        // Prefetching fully-cached batches is a no-op (no refill).
        let fills_before = k.fills.lock().unwrap().len();
        cache.prefetch(&k, &[2, 3], &mut scratch);
        assert_eq!(k.fills.lock().unwrap().len(), fills_before);
    }

    /// Drive the policy through one full window with `misses` misses (the
    /// rest hits) and return the block size after adaptation.
    fn window(policy: &mut PrefetchPolicy, misses: u32) -> usize {
        for t in 0..PREFETCH_WINDOW {
            policy.record(t < misses);
        }
        policy.block()
    }

    #[test]
    fn prefetch_policy_adapts_and_stays_bounded() {
        // Pins the adaptation policy: start at 8; miss rate ≥ 1/2 doubles,
        // ≤ 1/8 halves, in between holds; always within [MIN, MAX].
        let mut p = PrefetchPolicy::new();
        assert_eq!(p.block(), PREFETCH_START);
        // Mid-window observations never change the block.
        p.record(true);
        assert_eq!(p.block(), PREFETCH_START);
        for _ in 0..PREFETCH_WINDOW - 1 {
            p.record(true);
        }
        assert_eq!(p.block(), 16, "all-miss window doubles");
        assert_eq!(window(&mut p, PREFETCH_WINDOW / 2), 32, "rate 1/2 doubles");
        assert_eq!(window(&mut p, PREFETCH_WINDOW), 32, "clamped at MAX");
        // A mid rate (between 1/8 and 1/2) holds steady.
        assert_eq!(window(&mut p, PREFETCH_WINDOW / 4), 32, "rate 1/4 holds");
        // Low-miss windows shrink back down to the floor.
        assert_eq!(window(&mut p, PREFETCH_WINDOW / 8), 16, "rate 1/8 halves");
        assert_eq!(window(&mut p, 0), 8);
        assert_eq!(window(&mut p, 0), 4);
        assert_eq!(window(&mut p, 0), 4, "clamped at MIN");
        // And grows again when the workload turns miss-heavy.
        assert_eq!(window(&mut p, PREFETCH_WINDOW), 8);
    }

    #[test]
    fn cache_keeps_results_identical() {
        let ds = cluster_data(40, 13);
        let kernel = ResemblanceKernel { data: &ds };
        let big_cache = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                cache_rows: 4096,
                ..Default::default()
            },
        );
        let tiny_cache = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                cache_rows: 2,
                ..Default::default()
            },
        );
        for (a, b) in big_cache.coef.iter().zip(&tiny_cache.coef) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
