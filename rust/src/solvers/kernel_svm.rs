//! Nonlinear (kernel) SVM via SMO — the paper's §5.1 experiment substrate.
//!
//! The paper trained LIBSVM with a custom *resemblance kernel* and found it
//! infeasible on the raw data (>1 week), but tractable on the b-bit
//! estimated kernel. We implement the dual L1-SVM
//!
//!   max_α Σα_i − ½ ΣΣ α_i α_j y_i y_j K(i,j),   0 ≤ α_i ≤ C
//!
//! (no bias term, matching our linear solvers and the paper's LIBLINEAR
//! usage) with greedy maximal-violating-coordinate updates and an LRU row
//! cache, so the Gram matrix is computed lazily — exactly the regime where
//! estimated kernels from small signatures beat exact resemblance on
//! massive raw data.

use std::collections::HashMap;

/// A kernel function over example indices.
pub trait Kernel: Sync {
    fn n(&self) -> usize;
    fn label(&self, i: usize) -> f32;
    fn eval(&self, i: usize, j: usize) -> f64;

    /// Fill `out` with the full Gram row K(i, ·). The default evaluates
    /// pointwise; kernels with a batched path override it — [`BbitKernel`]
    /// fills the row with the packed store's SWAR Gram-row primitive
    /// (`match_count_row_div_into`), which is what makes the lazy
    /// row-cache fills cheap (§5.1).
    fn fill_row(&self, i: usize, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(self.n());
        for j in 0..self.n() {
            out.push(self.eval(i, j));
        }
    }
}

/// Resemblance kernel over raw sparse sets: K(i,j) = R(S_i, S_j) (PD by
/// Theorem 2).
pub struct ResemblanceKernel<'a> {
    pub data: &'a crate::data::sparse::SparseBinaryDataset,
}

impl Kernel for ResemblanceKernel<'_> {
    fn n(&self) -> usize {
        self.data.n()
    }
    fn label(&self, i: usize) -> f32 {
        self.data.label(i)
    }
    fn eval(&self, i: usize, j: usize) -> f64 {
        self.data.row_vec(i).resemblance(&self.data.row_vec(j))
    }
}

/// The b-bit estimated kernel: K(i,j) = P̂_b(i,j) = match_count/k — the
/// normalized Theorem-2 Gram matrix (PD as an average of PD matrices).
/// This is what made §5.1 tractable.
pub struct BbitKernel<'a> {
    pub sigs: &'a crate::hashing::bbit::BbitSignatureMatrix,
}

impl Kernel for BbitKernel<'_> {
    fn n(&self) -> usize {
        self.sigs.n()
    }
    fn label(&self, i: usize) -> f32 {
        self.sigs.label(i)
    }
    fn eval(&self, i: usize, j: usize) -> f64 {
        self.sigs.match_count(i, j) as f64 / self.sigs.k() as f64
    }

    fn fill_row(&self, i: usize, out: &mut Vec<f64>) {
        self.sigs.match_count_row_div_into(i, self.sigs.k() as f64, out);
    }
}

/// SMO options.
#[derive(Clone, Debug)]
pub struct KernelSvmOptions {
    pub c: f64,
    /// KKT violation tolerance.
    pub tol: f64,
    /// Hard cap on coordinate updates.
    pub max_updates: usize,
    /// Kernel row cache capacity (rows).
    pub cache_rows: usize,
}

impl Default for KernelSvmOptions {
    fn default() -> Self {
        Self {
            c: 1.0,
            tol: 1e-3,
            max_updates: 200_000,
            cache_rows: 512,
        }
    }
}

/// LRU-ish kernel row cache (random eviction — cheap and effective here).
struct RowCache {
    rows: HashMap<usize, Vec<f64>>,
    cap: usize,
    tick: u64,
}

impl RowCache {
    fn new(cap: usize) -> Self {
        Self {
            rows: HashMap::with_capacity(cap),
            cap,
            tick: 0,
        }
    }

    fn get<K: Kernel>(&mut self, k: &K, i: usize) -> &Vec<f64> {
        self.tick = self.tick.wrapping_add(0x9E37_79B9);
        if !self.rows.contains_key(&i) {
            if self.rows.len() >= self.cap {
                // Evict an arbitrary entry (HashMap iteration order).
                if let Some(&victim) = self.rows.keys().next() {
                    self.rows.remove(&victim);
                }
            }
            let mut row = Vec::new();
            k.fill_row(i, &mut row);
            self.rows.insert(i, row);
        }
        &self.rows[&i]
    }
}

/// A trained kernel SVM model: support-vector coefficients.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// α_i·y_i for every training point (zeros for non-SVs).
    pub coef: Vec<f64>,
    pub updates: usize,
    pub dual_objective: f64,
}

impl KernelModel {
    /// Decision value for an arbitrary kernel column (K(·, x) against all
    /// training points).
    pub fn score_with(&self, kcol: impl Fn(usize) -> f64) -> f64 {
        self.coef
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0.0)
            .map(|(i, &c)| c * kcol(i))
            .sum()
    }

    pub fn n_support(&self) -> usize {
        self.coef.iter().filter(|&&c| c != 0.0).count()
    }
}

/// Train the dual SVM by greedy coordinate ascent (single-coordinate SMO
/// without bias, valid because we solve the no-offset formulation).
pub fn train_kernel_svm<K: Kernel>(kernel: &K, opt: &KernelSvmOptions) -> KernelModel {
    let n = kernel.n();
    assert!(n > 0);
    let mut alpha = vec![0.0f64; n];
    // Gradient of the dual objective: g_i = 1 − y_i Σ_j α_j y_j K(i,j).
    let mut grad = vec![1.0f64; n];
    let mut cache = RowCache::new(opt.cache_rows);
    let diag: Vec<f64> = (0..n).map(|i| kernel.eval(i, i).max(1e-12)).collect();

    let mut updates = 0usize;
    while updates < opt.max_updates {
        // Maximal violating coordinate under the box 0 ≤ α ≤ C.
        let mut best = None;
        let mut best_v = opt.tol;
        for i in 0..n {
            let v = if alpha[i] <= 0.0 {
                grad[i].max(0.0)
            } else if alpha[i] >= opt.c {
                (-grad[i]).max(0.0)
            } else {
                grad[i].abs()
            };
            if v > best_v {
                best_v = v;
                best = Some(i);
            }
        }
        let Some(i) = best else { break };
        let old = alpha[i];
        let a_new = (old + grad[i] / diag[i]).clamp(0.0, opt.c);
        let delta = a_new - old;
        if delta == 0.0 {
            break;
        }
        alpha[i] = a_new;
        let yi = kernel.label(i) as f64;
        let row = cache.get(kernel, i);
        for j in 0..n {
            let yj = kernel.label(j) as f64;
            grad[j] -= delta * yi * yj * row[j];
        }
        updates += 1;
    }

    // Dual objective Σα − ½ αᵀQα = Σα − ½ Σ α_i (1 − g_i).
    let dual: f64 = alpha
        .iter()
        .zip(&grad)
        .map(|(&a, &g)| a - 0.5 * a * (1.0 - g))
        .sum();
    let coef: Vec<f64> = alpha
        .iter()
        .enumerate()
        .map(|(i, &a)| a * kernel.label(i) as f64)
        .collect();
    KernelModel {
        coef,
        updates,
        dual_objective: dual,
    }
}

/// Accuracy of a kernel model on held-out items given a cross-kernel
/// evaluator `cross(i_test, j_train)`.
pub fn kernel_accuracy<K: Kernel>(
    model: &KernelModel,
    n_test: usize,
    labels: impl Fn(usize) -> f32,
    cross: impl Fn(usize, usize) -> f64,
    _kernel: &K,
) -> f64 {
    if n_test == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in 0..n_test {
        let s = model.score_with(|j| cross(t, j));
        let pred = if s >= 0.0 { 1.0 } else { -1.0 };
        if pred == labels(t) {
            correct += 1;
        }
    }
    correct as f64 / n_test as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
    use crate::hashing::bbit::BbitSignatureMatrix;
    use crate::hashing::minwise::MinwiseHasher;
    use crate::rng::Xoshiro256;

    /// Two clusters of sets: positives share a core block, negatives share
    /// another — resemblance separates them.
    fn cluster_data(n: usize, seed: u64) -> SparseBinaryDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ds = SparseBinaryDataset::new(100_000);
        for i in 0..n {
            let pos = i % 2 == 0;
            let core: Vec<u64> = if pos { (0..40).collect() } else { (50..90).collect() };
            let mut idx = core;
            for _ in 0..20 {
                idx.push(100 + rng.gen_range(99_000));
            }
            ds.push(
                SparseBinaryVec::from_indices(idx),
                if pos { 1.0 } else { -1.0 },
            );
        }
        ds
    }

    #[test]
    fn resemblance_kernel_separates_clusters() {
        let ds = cluster_data(60, 3);
        let kernel = ResemblanceKernel { data: &ds };
        let model = train_kernel_svm(&kernel, &KernelSvmOptions::default());
        let mut correct = 0;
        for i in 0..ds.n() {
            let s = model.score_with(|j| kernel.eval(i, j));
            if (s >= 0.0) == (ds.label(i) > 0.0) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n() as f64 > 0.95, "acc {correct}/60");
        assert!(model.n_support() > 0);
    }

    #[test]
    fn bbit_kernel_matches_resemblance_kernel_accuracy() {
        // §5.1's point: the estimated kernel is as good as the exact one.
        let ds = cluster_data(60, 7);
        let h = MinwiseHasher::new(100_000, 128, 11);
        let mut sigs = BbitSignatureMatrix::new(128, 8);
        for i in 0..ds.n() {
            sigs.push_full_row(&h.signature(ds.row(i)), ds.label(i));
        }
        let kernel = BbitKernel { sigs: &sigs };
        let model = train_kernel_svm(&kernel, &KernelSvmOptions::default());
        let mut correct = 0;
        for i in 0..ds.n() {
            let s = model.score_with(|j| kernel.eval(i, j));
            if (s >= 0.0) == (ds.label(i) > 0.0) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n() as f64 > 0.95, "acc {correct}/60");
    }

    #[test]
    fn bbit_fill_row_matches_pointwise_eval() {
        let ds = cluster_data(24, 21);
        let h = MinwiseHasher::new(100_000, 33, 2); // ragged k·b
        for b in [1u32, 2, 4, 8] {
            let mut sigs = BbitSignatureMatrix::new(33, b);
            for i in 0..ds.n() {
                sigs.push_full_row(&h.signature(ds.row(i)), ds.label(i));
            }
            let kernel = BbitKernel { sigs: &sigs };
            let mut row = Vec::new();
            kernel.fill_row(7, &mut row);
            assert_eq!(row.len(), kernel.n());
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, kernel.eval(7, j), "b={b} j={j}");
            }
        }
    }

    #[test]
    fn dual_objective_increases_with_budget() {
        let ds = cluster_data(40, 5);
        let kernel = ResemblanceKernel { data: &ds };
        let small = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                max_updates: 5,
                tol: 0.0,
                ..Default::default()
            },
        );
        let big = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                max_updates: 5000,
                tol: 0.0,
                ..Default::default()
            },
        );
        assert!(big.dual_objective >= small.dual_objective - 1e-9);
    }

    #[test]
    fn alphas_respect_box() {
        let ds = cluster_data(30, 9);
        let kernel = ResemblanceKernel { data: &ds };
        let c = 0.5;
        let model = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                c,
                ..Default::default()
            },
        );
        for (i, &coef) in model.coef.iter().enumerate() {
            let a = coef * kernel.label(i) as f64; // recover α_i ≥ 0
            assert!(a >= -1e-12 && a <= c + 1e-12, "α_{i} = {a}");
        }
    }

    #[test]
    fn cache_keeps_results_identical() {
        let ds = cluster_data(40, 13);
        let kernel = ResemblanceKernel { data: &ds };
        let big_cache = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                cache_rows: 4096,
                ..Default::default()
            },
        );
        let tiny_cache = train_kernel_svm(
            &kernel,
            &KernelSvmOptions {
                cache_rows: 2,
                ..Default::default()
            },
        );
        for (a, b) in big_cache.coef.iter().zip(&tiny_cache.coef) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
