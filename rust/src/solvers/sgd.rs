//! Pegasos-style stochastic subgradient SVM (Shalev-Shwartz et al., ICML
//! 2007) — the SGD representative of the solver families the paper cites
//! in §4, and the rust twin of the AOT-compiled JAX train step (L2).
//!
//! Pegasos minimizes  λ/2·‖w‖² + (1/n)·Σ hinge(y_i w·x_i)  with step
//! η_t = 1/(λt) and the optional ‖w‖ ≤ 1/√λ projection. The paper's C maps
//! to λ = 1/(C·n).

use super::{Features, LinearModel};
use crate::rng::Xoshiro256;

/// The per-row loss the cyclic-epoch SGD core optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SgdLoss {
    /// Hinge subgradient — the Pegasos SVM update.
    Hinge,
    /// Logistic gradient on the same η_t = 1/(λt) schedule.
    Logistic,
}

impl SgdLoss {
    /// The byte a checkpoint records for this loss.
    pub fn code(self) -> u8 {
        match self {
            Self::Hinge => 0,
            Self::Logistic => 1,
        }
    }

    /// Inverse of [`Self::code`]; `None` for unknown bytes.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(Self::Hinge),
            1 => Some(Self::Logistic),
            _ => None,
        }
    }
}

/// The epoch-SGD state machine shared verbatim by the disk, in-memory and
/// resumable-session drivers (bit-identity across all of them depends on
/// there being exactly one `step`).
///
/// Every field is part of the model state a checkpoint must capture: the
/// weights AND the lazy scale, step counter and averaging accumulator —
/// restoring them all is what makes a resumed run continue the identical
/// float-op sequence. The fields are `pub(crate)` so the checkpoint codec
/// in [`crate::coordinator::session`] can serialize them exactly.
pub struct SgdCore {
    pub(crate) loss: SgdLoss,
    pub(crate) lambda: f64,
    pub(crate) w: Vec<f32>,
    /// Lazy scaling: actual weights are `w · w_scale`.
    pub(crate) w_scale: f64,
    pub(crate) t: usize,
    pub(crate) total_steps: usize,
    pub(crate) avg: Option<Vec<f64>>,
    pub(crate) avg_count: usize,
}

impl SgdCore {
    pub fn new(loss: SgdLoss, dim: usize, lambda: f64, total_steps: usize, average: bool) -> Self {
        Self {
            loss,
            lambda,
            w: vec![0.0f32; dim],
            w_scale: 1.0,
            t: 0,
            total_steps,
            avg: if average { Some(vec![0.0f64; dim]) } else { None },
            avg_count: 0,
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> usize {
        self.t
    }

    /// The λt schedule's denominator n·epochs this core was sized for.
    pub fn total_steps(&self) -> usize {
        self.total_steps
    }

    /// One SGD step on row `i` of `feats` (mirrors [`train_pegasos`]'s
    /// inner loop, minus the random row sampling and the ball projection —
    /// and with it the incremental ‖w‖² bookkeeping, so each update is one
    /// dot + one axpy pass). Generic over [`Features`]: packed stores step
    /// through the virtual expansion, dense stores through their f32 rows.
    pub fn step<Ft: Features>(&mut self, feats: &Ft, i: usize) {
        self.t += 1;
        let eta = 1.0 / (self.lambda * self.t as f64);
        let y = feats.label(i) as f64;
        let margin = y * feats.dot(i, &self.w) * self.w_scale;

        // w ← (1 − η λ) w  [+ s·x_i];  shrink = 1 − 1/t zeroes w at t = 1.
        let shrink = 1.0 - eta * self.lambda;
        if shrink <= 0.0 {
            self.w.iter_mut().for_each(|x| *x = 0.0);
            self.w_scale = 1.0;
        } else {
            self.w_scale *= shrink;
        }
        let s = match self.loss {
            SgdLoss::Hinge => {
                if margin < 1.0 {
                    eta * y
                } else {
                    0.0
                }
            }
            // η·y·σ(−margin); exp overflow saturates s to 0, which is the
            // correct limit for confidently-classified rows.
            SgdLoss::Logistic => eta * y / (1.0 + margin.exp()),
        };
        if s != 0.0 {
            feats.axpy(i, s / self.w_scale, &mut self.w);
        }
        // Re-materialize the lazy scale before f32 head-room runs out.
        if self.w_scale < 1e-4 {
            for x in self.w.iter_mut() {
                *x = (*x as f64 * self.w_scale) as f32;
            }
            self.w_scale = 1.0;
        }
        // Suffix averaging over the second half of all steps.
        if let Some(a) = self.avg.as_mut() {
            if self.t > self.total_steps / 2 {
                for (aj, &wj) in a.iter_mut().zip(&self.w) {
                    *aj += wj as f64 * self.w_scale;
                }
                self.avg_count += 1;
            }
        }
    }

    /// Final dense weights (averaged iterate when enabled).
    pub fn into_weights(self) -> Vec<f32> {
        match self.avg {
            Some(a) if self.avg_count > 0 => {
                a.iter().map(|&x| (x / self.avg_count as f64) as f32).collect()
            }
            _ => self.w.iter().map(|&x| (x as f64 * self.w_scale) as f32).collect(),
        }
    }

    /// [`Self::into_weights`] without consuming the core — the exact same
    /// float-op sequence, for mid-stream snapshot publication: the online
    /// trainer keeps stepping the very state it just snapshotted, so a
    /// published snapshot is precisely "the model had training stopped
    /// here", bit for bit.
    pub fn weights_snapshot(&self) -> Vec<f32> {
        match &self.avg {
            Some(a) if self.avg_count > 0 => {
                a.iter().map(|&x| (x / self.avg_count as f64) as f32).collect()
            }
            _ => self.w.iter().map(|&x| (x as f64 * self.w_scale) as f32).collect(),
        }
    }
}

/// Pegasos options.
#[derive(Clone, Debug)]
pub struct PegasosOptions {
    /// The paper's C; λ = 1/(C·n).
    pub c: f64,
    /// Total SGD steps.
    pub steps: usize,
    /// Apply the ball projection ‖w‖ ≤ 1/√λ after each step. Off by
    /// default: the Pegasos authors' later analysis showed it unnecessary,
    /// and with lazy scaling it costs numeric head-room.
    pub project: bool,
    /// Average the trailing half of iterates (suffix averaging).
    pub average: bool,
    pub seed: u64,
}

impl Default for PegasosOptions {
    fn default() -> Self {
        Self {
            c: 1.0,
            steps: 100_000,
            project: false,
            average: true,
            seed: 1,
        }
    }
}

/// Train by Pegasos SGD. Generic over [`Features`] — binary substrates
/// run the identical float-op sequence as before the trait split (the
/// blanket impl delegates to the same defaults), dense f32 sketches plug
/// straight in.
pub fn train_pegasos<Ft: Features>(feats: &Ft, opt: &PegasosOptions) -> LinearModel {
    let n = feats.n();
    let dim = feats.dim();
    assert!(n > 0);
    let lambda = 1.0 / (opt.c * n as f64);
    let mut w = vec![0.0f32; dim];
    let mut w_scale = 1.0f64; // lazy scaling: actual weights are w·w_scale
    let mut avg = if opt.average {
        Some(vec![0.0f64; dim])
    } else {
        None
    };
    let mut avg_count = 0usize;
    let mut rng = Xoshiro256::seed_from_u64(opt.seed);
    let mut norm_sq = 0.0f64; // ‖w_scale·w‖², maintained incrementally

    for t in 1..=opt.steps {
        let i = rng.gen_range(n as u64) as usize;
        let eta = 1.0 / (lambda * t as f64);
        let y = feats.label(i) as f64;
        let margin = y * feats.dot(i, &w) * w_scale;

        // w ← (1 − η λ) w  [+ η y x_i if margin < 1]
        let shrink = 1.0 - eta * lambda;
        // shrink = 1 − 1/t; at t = 1 this zeroes w (Pegasos does exactly this).
        if shrink <= 0.0 {
            w.iter_mut().for_each(|x| *x = 0.0);
            w_scale = 1.0;
            norm_sq = 0.0;
        } else {
            w_scale *= shrink;
            norm_sq *= shrink * shrink;
        }
        if margin < 1.0 {
            let add = eta * y / w_scale; // store unscaled
            // norm update: ‖v + s·x‖² = ‖v‖² + 2 s ⟨v, x⟩ + s²·‖x‖²
            // (‖x‖² = nnz on binary rows).
            let dot_before = feats.dot(i, &w);
            feats.axpy(i, add, &mut w);
            let s = eta * y;
            norm_sq += 2.0 * s * dot_before * w_scale + s * s * feats.row_norm_sq(i);
        }
        if opt.project && norm_sq > 0.0 {
            let bound = 1.0 / lambda; // ‖w‖² ≤ 1/λ
            if norm_sq > bound {
                let f = (bound / norm_sq).sqrt();
                w_scale *= f;
                norm_sq = bound;
            }
        }
        // Re-materialize the lazy scale before f32 head-room runs out:
        // unscaled entries grow like 1/w_scale and lose precision.
        if w_scale < 1e-4 {
            for x in w.iter_mut() {
                *x = (*x as f64 * w_scale) as f32;
            }
            w_scale = 1.0;
        }
        // Suffix averaging over the second half.
        if let Some(ref mut a) = avg {
            if t > opt.steps / 2 {
                for (aj, &wj) in a.iter_mut().zip(&w) {
                    *aj += wj as f64 * w_scale;
                }
                avg_count += 1;
            }
        }
    }

    let w_final: Vec<f32> = match avg {
        Some(a) if avg_count > 0 => a.iter().map(|&x| (x / avg_count as f64) as f32).collect(),
        _ => w.iter().map(|&x| (x as f64 * w_scale) as f32).collect(),
    };
    let objective = pegasos_objective(feats, &w_final, lambda);
    LinearModel {
        w: w_final,
        iters: opt.steps,
        objective,
    }
}

/// λ/2 ‖w‖² + (1/n) Σ hinge.
pub fn pegasos_objective<Ft: Features>(feats: &Ft, w: &[f32], lambda: f64) -> f64 {
    let reg = 0.5 * lambda * w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
    let mut loss = 0.0;
    for i in 0..feats.n() {
        let m = 1.0 - feats.label(i) as f64 * feats.dot(i, w);
        if m > 0.0 {
            loss += m;
        }
    }
    reg + loss / feats.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
    use crate::rng::Xoshiro256;

    fn toy(n: usize, dim: u64, seed: u64) -> SparseBinaryDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ds = SparseBinaryDataset::new(dim);
        for i in 0..n {
            let pos = i % 2 == 0;
            let mut idx = vec![if pos { 0u64 } else { 1u64 }];
            for _ in 0..4 {
                idx.push(2 + rng.gen_range(dim - 2));
            }
            ds.push(
                SparseBinaryVec::from_indices(idx),
                if pos { 1.0 } else { -1.0 },
            );
        }
        ds
    }

    #[test]
    fn pegasos_learns_separable_data() {
        let ds = toy(200, 100, 3);
        let model = train_pegasos(
            &ds,
            &PegasosOptions {
                steps: 50_000,
                ..Default::default()
            },
        );
        assert!(model.accuracy(&ds) > 0.97, "acc {}", model.accuracy(&ds));
    }

    #[test]
    fn pegasos_objective_close_to_dcd_optimum() {
        // Both optimize (up to loss scaling) the same problem; Pegasos
        // should land near the DCD L1-SVM optimum.
        use crate::solvers::linear_svm::{train_svm, SvmLoss, SvmOptions};
        let ds = toy(150, 60, 5);
        let c = 1.0;
        let dcd = train_svm(
            &ds,
            &SvmOptions {
                c,
                loss: SvmLoss::L1,
                max_iter: 300,
                tol: 1e-6,
                ..Default::default()
            },
        );
        let lambda = 1.0 / (c * ds.n() as f64);
        let dcd_obj = pegasos_objective(&ds, &dcd.w, lambda);
        let peg = train_pegasos(
            &ds,
            &PegasosOptions {
                c,
                steps: 400_000,
                ..Default::default()
            },
        );
        assert!(
            peg.objective < dcd_obj * 1.10 + 1e-6,
            "pegasos {} vs dcd {}",
            peg.objective,
            dcd_obj
        );
    }

    #[test]
    fn sgd_loss_codes_roundtrip() {
        for loss in [SgdLoss::Hinge, SgdLoss::Logistic] {
            assert_eq!(SgdLoss::from_code(loss.code()), Some(loss));
        }
        assert_eq!(SgdLoss::from_code(9), None);
    }

    #[test]
    fn core_learns_and_reports_steps() {
        let ds = toy(100, 50, 7);
        let lambda = 1.0 / ds.n() as f64;
        let total = 40 * ds.n();
        let mut core = SgdCore::new(SgdLoss::Hinge, 50, lambda, total, true);
        for _ in 0..40 {
            for i in 0..ds.n() {
                core.step(&ds, i);
            }
        }
        assert_eq!(core.steps(), total);
        assert_eq!(core.total_steps(), total);
        let w = core.into_weights();
        let model = LinearModel {
            w,
            iters: total,
            objective: 0.0,
        };
        assert!(model.accuracy(&ds) > 0.9, "acc {}", model.accuracy(&ds));
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = toy(50, 30, 1);
        let a = train_pegasos(&ds, &PegasosOptions::default());
        let b = train_pegasos(&ds, &PegasosOptions::default());
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn weights_finite_across_c_range() {
        let ds = toy(80, 40, 9);
        for c in [1e-3, 0.1, 10.0] {
            let m = train_pegasos(
                &ds,
                &PegasosOptions {
                    c,
                    steps: 20_000,
                    ..Default::default()
                },
            );
            assert!(m.w.iter().all(|x| x.is_finite()), "C={c}");
        }
    }
}
