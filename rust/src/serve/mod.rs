//! Online scoring service over a saved [`ModelArtifact`].
//!
//! The batch pipeline ends at a `MODEL` file; this subsystem serves it:
//! a long-lived, std-only TCP server (`serve` CLI verb) answering
//! micro-batches of raw libsvm-style sparse rows with scores that are
//! **bit-identical** to offline [`predict_artifact`], plus an atomic hot
//! model swap so a freshly retrained artifact can be published under
//! load without dropping or mixing a single in-flight request.
//!
//! Layout:
//!
//! * [`protocol`] — the length-prefixed, CRC-checked binary frame codec
//!   (header byte table documented in [`crate::store`], enforced by
//!   bbml-lint R4);
//! * [`slot`] — [`ModelSlot`], the atomically swappable published model
//!   with scheme/input-domain compatibility validation;
//! * [`server`] — worker pool, per-worker encoder reuse (the PR-2
//!   buffer contract), graceful shutdown, mtime watch;
//! * [`stats`] — [`ServeStats`] gauges (p50/p95/p99 latency, rows/s,
//!   swap count, queue depth) reported as JSON;
//! * [`client`] — [`ScoreClient`], the blocking client used by the
//!   `score` verb, tests and `bench_serving`.
//!
//! # Concurrency contracts (enforced by bbml-lint R7/R8)
//!
//! **Atomics — gauge vs handoff.** Every atomic in the subsystem is one
//! of two kinds, and the ordering follows from the kind, never from
//! caution. A *gauge* is monitoring output no thread acts on (the
//! [`ServeStats`] counters, the store reader's residency counters):
//! `Ordering::Relaxed`, because nothing is published through it. A
//! *handoff* publishes state another thread acts on (the server stop
//! flags, [`ModelSlot`]'s swap counter): `Acquire` loads, `Release`
//! stores, `AcqRel` read-modify-writes — the observer of the flag must
//! also observe what the flagger wrote before raising it. `SeqCst`
//! appears nowhere: where a handoff needs more than acquire/release
//! pairing it should use a lock, not a stronger fence "to be safe".
//! Declarations that deviate from the type-based default (numeric =
//! gauge, `AtomicBool` = handoff) carry a
//! `// bbml-lint: atomic(gauge|handoff)` annotation.
//!
//! **Lock order.** Nested lock acquisitions crate-wide follow the
//! declared order `rx < inner < latency_us < cache < records` (acquire
//! left before right; see `analysis::rules::LOCK_ORDER`). In practice
//! the serving path holds at most one lock at a time — the worker queue
//! mutex (`rx`) is released before a connection is served, the slot's
//! `inner` write lock covers only the pointer swap, and the `latency_us`
//! reservoir push happens after scoring with no other guard live. R7
//! additionally rejects blocking calls (I/O, `recv`, `sleep`, `join`)
//! while any guard is held; the single sanctioned exception — blocking
//! on `rx.recv()` *is* the multi-consumer design — is suppressed with a
//! reason at the site.
//!
//! [`ModelArtifact`]: crate::store::ModelArtifact
//! [`predict_artifact`]: crate::coordinator::trainer::predict_artifact

pub mod client;
pub mod protocol;
pub mod server;
pub mod slot;
pub mod stats;

pub use client::ScoreClient;
pub use protocol::{FrameHeader, FrameType, FRAME_HEADER_LEN, FRAME_MAGIC, FRAME_VERSION};
pub use server::{install_signal_handlers, serve, stop_requested, BatchScorer, ServeOptions};
pub use slot::{ModelSlot, ServedModel};
pub use stats::ServeStats;
