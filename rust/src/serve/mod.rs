//! Online scoring service over a saved [`ModelArtifact`].
//!
//! The batch pipeline ends at a `MODEL` file; this subsystem serves it:
//! a long-lived, std-only TCP server (`serve` CLI verb) answering
//! micro-batches of raw libsvm-style sparse rows with scores that are
//! **bit-identical** to offline [`predict_artifact`], plus an atomic hot
//! model swap so a freshly retrained artifact can be published under
//! load without dropping or mixing a single in-flight request.
//!
//! Layout:
//!
//! * [`protocol`] — the length-prefixed, CRC-checked binary frame codec
//!   (header byte table documented in [`crate::store`], enforced by
//!   bbml-lint R4);
//! * [`slot`] — [`ModelSlot`], the atomically swappable published model
//!   with scheme/input-domain compatibility validation;
//! * [`server`] — worker pool, per-worker encoder reuse (the PR-2
//!   buffer contract), graceful shutdown, mtime watch;
//! * [`stats`] — [`ServeStats`] gauges (p50/p95/p99 latency, rows/s,
//!   swap count, queue depth) reported as JSON;
//! * [`client`] — [`ScoreClient`], the blocking client used by the
//!   `score` verb, tests and `bench_serving`.
//!
//! [`ModelArtifact`]: crate::store::ModelArtifact
//! [`predict_artifact`]: crate::coordinator::trainer::predict_artifact

pub mod client;
pub mod protocol;
pub mod server;
pub mod slot;
pub mod stats;

pub use client::ScoreClient;
pub use protocol::{FrameHeader, FrameType, FRAME_HEADER_LEN, FRAME_MAGIC, FRAME_VERSION};
pub use server::{install_signal_handlers, serve, stop_requested, BatchScorer, ServeOptions};
pub use slot::{ModelSlot, ServedModel};
pub use stats::ServeStats;
