//! The serving wire protocol: length-prefixed, CRC-checked binary frames
//! over a plain TCP stream (no async, no new deps — std all the way down).
//!
//! Every frame is a fixed [`FRAME_HEADER_LEN`]-byte header followed by a
//! payload whose length and CRC-32 the header states up front, mirroring
//! the framed-blob envelope discipline of [`crate::store::format`]: a
//! reader always knows exactly how many bytes to consume next, and a
//! corrupted or truncated frame is `InvalidData`, never a mis-parse. The
//! byte-by-byte header table lives in [`crate::store`]'s module docs next
//! to the shard and framed-blob tables, and bbml-lint's `format-drift`
//! rule (R4) holds [`FrameHeader::encode`] to it.
//!
//! Frame types (the `frame_type` header field, u32):
//!
//! | code | frame            | payload                                      |
//! |------|------------------|----------------------------------------------|
//! | 0    | `ScoreRequest`   | u32 n_rows, then per row u32 nnz + nnz×u64   |
//! |      |                  | sorted unique shingle indices                |
//! | 1    | `ScoreResponse`  | u32 model_crc32, u32 n, then n×f64 scores    |
//! |      |                  | (IEEE-754 bit patterns, LE)                  |
//! | 2    | `Reload`         | u32 len + utf8 model path (len 0 = re-read   |
//! |      |                  | the currently served file)                   |
//! | 3    | `ReloadOk`       | u32 weights_crc32 of the newly published model|
//! | 4    | `Shutdown`       | empty                                        |
//! | 5    | `ShutdownOk`     | empty                                        |
//! | 6    | `Stats`          | empty                                        |
//! | 7    | `StatsResponse`  | utf8 JSON gauges object                      |
//! | 8    | `Error`          | utf8 message                                 |
//! | 9    | `RowBatch`       | u32 n_rows, then per row f32 label + u32 nnz |
//! |      |                  | + nnz×u64 sorted indices (online ingest)     |
//! | 10   | `RowBatchAck`    | u64 rows accepted from the batch             |
//!
//! Scores are shipped as raw `f64::to_bits` words so a served batch is
//! **bit-identical** to the offline [`predict_artifact`] scores — the
//! protocol never rounds through text.
//!
//! [`predict_artifact`]: crate::coordinator::trainer::predict_artifact

use std::io::{self, Read, Write};

use crate::store::format::{crc32, ByteReader};

/// Frame magic — first 8 bytes of every frame on the wire.
pub const FRAME_MAGIC: [u8; 8] = *b"BBSERVE\0";
/// Current serve wire-protocol version.
pub const FRAME_VERSION: u32 = 1;
/// Fixed frame header length in bytes.
pub const FRAME_HEADER_LEN: usize = 32;
/// Upper bound on a single frame's payload (sanity guard against reading
/// garbage lengths from a corrupt or hostile stream).
pub const MAX_FRAME_PAYLOAD: u64 = 1 << 30;

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("serve frame: {msg}"))
}

/// The frame-type registry (header `frame_type` field). Codes are wire
/// bytes: stable, explicit, and rejected when unknown — same posture as
/// [`Scheme::code`].
///
/// [`Scheme::code`]: crate::hashing::feature_map::Scheme::code
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameType {
    ScoreRequest,
    ScoreResponse,
    Reload,
    ReloadOk,
    Shutdown,
    ShutdownOk,
    Stats,
    StatsResponse,
    Error,
    /// A labeled training micro-batch for the online trainer's socket
    /// source (same framing envelope as scoring, different direction).
    RowBatch,
    /// Ingest acknowledgement: rows accepted from the preceding batch.
    RowBatchAck,
}

impl FrameType {
    /// The wire code (header bytes 12–16).
    pub fn code(self) -> u32 {
        match self {
            Self::ScoreRequest => 0,
            Self::ScoreResponse => 1,
            Self::Reload => 2,
            Self::ReloadOk => 3,
            Self::Shutdown => 4,
            Self::ShutdownOk => 5,
            Self::Stats => 6,
            Self::StatsResponse => 7,
            Self::Error => 8,
            Self::RowBatch => 9,
            Self::RowBatchAck => 10,
        }
    }

    /// Inverse of [`Self::code`]; `None` for unknown codes (a newer
    /// peer?) — callers reject, never guess.
    pub fn from_code(code: u32) -> Option<Self> {
        Some(match code {
            0 => Self::ScoreRequest,
            1 => Self::ScoreResponse,
            2 => Self::Reload,
            3 => Self::ReloadOk,
            4 => Self::Shutdown,
            5 => Self::ShutdownOk,
            6 => Self::Stats,
            7 => Self::StatsResponse,
            8 => Self::Error,
            9 => Self::RowBatch,
            10 => Self::RowBatchAck,
            _ => return None,
        })
    }
}

/// The decoded fixed frame header. Field order and widths are documented
/// byte-by-byte in [`crate::store`]'s module docs ("Serve wire frames");
/// [`Self::encode`] is checked against that table by bbml-lint R4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    /// Protocol version ([`FRAME_VERSION`] for writers).
    pub version: u32,
    /// [`FrameType::code`] of the frame.
    pub frame_type: u32,
    /// Payload bytes following the header.
    pub payload_len: u64,
    /// CRC-32 (poly 0xEDB88320, reflected) of the payload.
    pub payload_crc32: u32,
}

impl FrameHeader {
    /// Build the header for `payload` of the given type.
    pub fn for_payload(frame_type: FrameType, payload: &[u8]) -> Self {
        Self {
            version: FRAME_VERSION,
            frame_type: frame_type.code(),
            payload_len: payload.len() as u64,
            payload_crc32: crc32(payload),
        }
    }

    /// Serialize to wire bytes (layout documented in [`crate::store`]).
    pub fn encode(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut out = [0u8; FRAME_HEADER_LEN];
        out[0..8].copy_from_slice(&FRAME_MAGIC);
        out[8..12].copy_from_slice(&self.version.to_le_bytes());
        out[12..16].copy_from_slice(&self.frame_type.to_le_bytes());
        out[16..24].copy_from_slice(&self.payload_len.to_le_bytes());
        out[24..28].copy_from_slice(&self.payload_crc32.to_le_bytes());
        out
    }

    /// Decode + validate magic, version and the payload-length bound.
    /// The payload CRC is checked later, by [`Self::verify_payload`],
    /// once the payload bytes have actually arrived.
    pub fn decode(buf: &[u8; FRAME_HEADER_LEN]) -> io::Result<Self> {
        if buf[0..8] != FRAME_MAGIC {
            return Err(bad(format!("bad magic {:02x?}", &buf[0..8])));
        }
        let mut r = ByteReader::new(&buf[8..]);
        let version = r.u32()?;
        let frame_type = r.u32()?;
        let payload_len = r.u64()?;
        let payload_crc32 = r.u32()?;
        if version == 0 || version > FRAME_VERSION {
            return Err(bad(format!(
                "unsupported version {version} (this build speaks ≤ {FRAME_VERSION})"
            )));
        }
        if payload_len > MAX_FRAME_PAYLOAD {
            return Err(bad(format!(
                "payload_len {payload_len} exceeds the {MAX_FRAME_PAYLOAD}-byte bound"
            )));
        }
        Ok(Self {
            version,
            frame_type,
            payload_len,
            payload_crc32,
        })
    }

    /// The decoded frame type, rejecting unknown codes.
    pub fn frame_type(&self) -> io::Result<FrameType> {
        FrameType::from_code(self.frame_type)
            .ok_or_else(|| bad(format!("unknown frame type {}", self.frame_type)))
    }

    /// Verify the received payload against the header's length + CRC.
    pub fn verify_payload(&self, payload: &[u8]) -> io::Result<()> {
        if payload.len() as u64 != self.payload_len {
            return Err(bad(format!(
                "payload length {} != header payload_len {}",
                payload.len(),
                self.payload_len
            )));
        }
        let got = crc32(payload);
        if got != self.payload_crc32 {
            return Err(bad(format!(
                "payload CRC mismatch: header {:#010x}, computed {got:#010x}",
                self.payload_crc32
            )));
        }
        Ok(())
    }
}

/// Write one complete frame (header + payload) to the stream.
pub fn write_frame<W: Write>(w: &mut W, ft: FrameType, payload: &[u8]) -> io::Result<()> {
    let header = FrameHeader::for_payload(ft, payload);
    w.write_all(&header.encode())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one complete frame from a blocking stream (the client path; the
/// server uses an interruptible reader around the same header/verify
/// codec). Returns `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<(FrameType, Vec<u8>)>> {
    let mut head = [0u8; FRAME_HEADER_LEN];
    // Distinguish clean EOF (no bytes at all) from a truncated header.
    let mut got = 0usize;
    while got < head.len() {
        let n = r.read(&mut head[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(bad(format!("EOF after {got} of {FRAME_HEADER_LEN} header bytes")));
        }
        got += n;
    }
    let header = FrameHeader::decode(&head)?;
    let mut payload = vec![0u8; header.payload_len as usize];
    r.read_exact(&mut payload)?;
    header.verify_payload(&payload)?;
    Ok(Some((header.frame_type()?, payload)))
}

// ------------------------------------------------------ payload codecs ----

/// Encode a score request: a micro-batch of raw sparse rows (sorted
/// unique shingle indices, libsvm-style).
pub fn encode_score_request(rows: &[Vec<u64>]) -> Vec<u8> {
    let nnz: usize = rows.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(4 + rows.len() * 4 + nnz * 8);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for row in rows {
        out.extend_from_slice(&(row.len() as u32).to_le_bytes());
        for &idx in row {
            out.extend_from_slice(&idx.to_le_bytes());
        }
    }
    out
}

/// Decode a score request. Truncation / trailing bytes are `InvalidData`;
/// row *content* validation (index < model dim, sortedness) is the
/// scorer's job, where the active model is known.
pub fn decode_score_request(payload: &[u8]) -> io::Result<Vec<Vec<u64>>> {
    let mut r = ByteReader::new(payload);
    let n_rows = r.u32()? as usize;
    let mut rows = Vec::with_capacity(n_rows.min(1 << 20));
    for _ in 0..n_rows {
        let nnz = r.u32()? as usize;
        rows.push(r.u64_vec(nnz)?);
    }
    r.finish()?;
    Ok(rows)
}

/// Encode a score response: the serving model's `weights_crc32`
/// fingerprint plus one f64 score per requested row, shipped as raw bit
/// patterns so the client sees exactly what the scorer computed.
pub fn encode_score_response(model_crc32: u32, scores: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + scores.len() * 8);
    out.extend_from_slice(&model_crc32.to_le_bytes());
    out.extend_from_slice(&(scores.len() as u32).to_le_bytes());
    for &s in scores {
        out.extend_from_slice(&s.to_bits().to_le_bytes());
    }
    out
}

/// Decode a score response into `(model_crc32, scores)`.
pub fn decode_score_response(payload: &[u8]) -> io::Result<(u32, Vec<f64>)> {
    let mut r = ByteReader::new(payload);
    let model_crc32 = r.u32()?;
    let n = r.u32()? as usize;
    let mut scores = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        scores.push(f64::from_bits(r.u64()?));
    }
    r.finish()?;
    Ok((model_crc32, scores))
}

/// Encode a reload request (`None` = re-read the currently served path).
pub fn encode_reload(path: Option<&str>) -> Vec<u8> {
    let p = path.unwrap_or("");
    let mut out = Vec::with_capacity(4 + p.len());
    out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    out.extend_from_slice(p.as_bytes());
    out
}

/// Decode a reload request.
pub fn decode_reload(payload: &[u8]) -> io::Result<Option<String>> {
    let mut r = ByteReader::new(payload);
    let len = r.u32()? as usize;
    if payload.len() != 4 + len {
        return Err(bad(format!(
            "reload path length {len} disagrees with payload size {}",
            payload.len()
        )));
    }
    if len == 0 {
        return Ok(None);
    }
    let path = std::str::from_utf8(&payload[4..])
        .map_err(|e| bad(format!("reload path is not utf8: {e}")))?;
    Ok(Some(path.to_string()))
}

/// Encode a reload acknowledgement carrying the new model fingerprint.
pub fn encode_reload_ok(weights_crc32: u32) -> Vec<u8> {
    weights_crc32.to_le_bytes().to_vec()
}

/// Decode a reload acknowledgement.
pub fn decode_reload_ok(payload: &[u8]) -> io::Result<u32> {
    let mut r = ByteReader::new(payload);
    let crc = r.u32()?;
    r.finish()?;
    Ok(crc)
}

/// Encode a training row batch for the online trainer's socket source:
/// per row, the ±1 label and the sorted raw sparse indices.
pub fn encode_row_batch(rows: &[(f32, Vec<u64>)]) -> Vec<u8> {
    let nnz: usize = rows.iter().map(|(_, r)| r.len()).sum();
    let mut out = Vec::with_capacity(4 + rows.len() * 8 + nnz * 8);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for (label, row) in rows {
        out.extend_from_slice(&label.to_le_bytes());
        out.extend_from_slice(&(row.len() as u32).to_le_bytes());
        for &idx in row {
            out.extend_from_slice(&idx.to_le_bytes());
        }
    }
    out
}

/// Decode a training row batch. Truncation / trailing bytes are
/// `InvalidData`; row *content* validation (sortedness, index < encoder
/// dim) is the row source's job, where the live spec is known.
pub fn decode_row_batch(payload: &[u8]) -> io::Result<Vec<(f32, Vec<u64>)>> {
    let mut r = ByteReader::new(payload);
    let n_rows = r.u32()? as usize;
    let mut rows = Vec::with_capacity(n_rows.min(1 << 20));
    for _ in 0..n_rows {
        let label = f32::from_le_bytes(r.u32()?.to_le_bytes());
        let nnz = r.u32()? as usize;
        rows.push((label, r.u64_vec(nnz)?));
    }
    r.finish()?;
    Ok(rows)
}

/// Encode an ingest acknowledgement (rows accepted from the batch).
pub fn encode_row_batch_ack(rows: u64) -> Vec<u8> {
    rows.to_le_bytes().to_vec()
}

/// Decode an ingest acknowledgement.
pub fn decode_row_batch_ack(payload: &[u8]) -> io::Result<u64> {
    let mut r = ByteReader::new(payload);
    let rows = r.u64()?;
    r.finish()?;
    Ok(rows)
}

/// Decode a utf8 text payload (`StatsResponse` / `Error` frames).
pub fn decode_text(payload: &[u8]) -> io::Result<String> {
    std::str::from_utf8(payload)
        .map(str::to_string)
        .map_err(|e| bad(format!("text payload is not utf8: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_type_codes_roundtrip_and_reject_unknown() {
        for ft in [
            FrameType::ScoreRequest,
            FrameType::ScoreResponse,
            FrameType::Reload,
            FrameType::ReloadOk,
            FrameType::Shutdown,
            FrameType::ShutdownOk,
            FrameType::Stats,
            FrameType::StatsResponse,
            FrameType::Error,
            FrameType::RowBatch,
            FrameType::RowBatchAck,
        ] {
            assert_eq!(FrameType::from_code(ft.code()), Some(ft));
        }
        assert_eq!(FrameType::from_code(11), None);
        assert_eq!(FrameType::from_code(u32::MAX), None);
    }

    #[test]
    fn header_encode_decode_roundtrip() {
        let h = FrameHeader::for_payload(FrameType::ScoreRequest, b"abc");
        assert_eq!(h.version, FRAME_VERSION);
        assert_eq!(h.payload_len, 3);
        let back = FrameHeader::decode(&h.encode()).unwrap();
        assert_eq!(back, h);
        back.verify_payload(b"abc").unwrap();
        assert!(back.verify_payload(b"abd").is_err());
        assert!(back.verify_payload(b"ab").is_err());
    }

    #[test]
    fn header_rejects_bad_magic_version_and_oversized_len() {
        let h = FrameHeader::for_payload(FrameType::Stats, b"");
        let mut bytes = h.encode();
        bytes[0] ^= 0xFF;
        assert!(FrameHeader::decode(&bytes).is_err());

        let mut bytes = h.encode();
        bytes[8..12].copy_from_slice(&(FRAME_VERSION + 1).to_le_bytes());
        assert!(FrameHeader::decode(&bytes).is_err());
        bytes[8..12].copy_from_slice(&0u32.to_le_bytes());
        assert!(FrameHeader::decode(&bytes).is_err());

        let mut bytes = h.encode();
        bytes[16..24].copy_from_slice(&(MAX_FRAME_PAYLOAD + 1).to_le_bytes());
        assert!(FrameHeader::decode(&bytes).is_err());

        // Unknown frame types decode (header-level) but refuse to type.
        let mut bytes = h.encode();
        bytes[12..16].copy_from_slice(&99u32.to_le_bytes());
        let hd = FrameHeader::decode(&bytes).unwrap();
        assert!(hd.frame_type().is_err());
    }

    #[test]
    fn frame_write_read_roundtrip_over_a_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameType::Error, b"boom").unwrap();
        write_frame(&mut wire, FrameType::Shutdown, b"").unwrap();
        let mut cur = std::io::Cursor::new(wire);
        let (ft, p) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!((ft, p.as_slice()), (FrameType::Error, &b"boom"[..]));
        let (ft, p) = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!((ft, p.len()), (FrameType::Shutdown, 0));
        // Clean EOF at a frame boundary.
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn truncated_header_and_corrupt_payload_are_errors() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameType::Error, b"boom").unwrap();
        // Truncate mid-header.
        let mut cur = std::io::Cursor::new(&wire[..10]);
        assert!(read_frame(&mut cur).is_err());
        // Flip a payload bit: CRC catches it.
        let mut corrupt = wire.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0x01;
        let mut cur = std::io::Cursor::new(corrupt);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn score_request_roundtrip_and_truncation() {
        let rows = vec![vec![1u64, 5, 900], vec![], vec![42]];
        let payload = encode_score_request(&rows);
        assert_eq!(decode_score_request(&payload).unwrap(), rows);
        assert!(decode_score_request(&payload[..payload.len() - 1]).is_err());
        let mut extra = payload.clone();
        extra.push(0);
        assert!(decode_score_request(&extra).is_err());
        // Empty batch is legal.
        assert_eq!(
            decode_score_request(&encode_score_request(&[])).unwrap(),
            Vec::<Vec<u64>>::new()
        );
    }

    #[test]
    fn score_response_is_bit_exact() {
        let scores = vec![1.5, -0.0, f64::MIN_POSITIVE, -3.25e300];
        let payload = encode_score_response(0xDEADBEEF, &scores);
        let (crc, back) = decode_score_response(&payload).unwrap();
        assert_eq!(crc, 0xDEADBEEF);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back), bits(&scores));
        assert!(decode_score_response(&payload[..5]).is_err());
    }

    #[test]
    fn row_batch_roundtrip_is_bit_exact_and_rejects_truncation() {
        let rows = vec![
            (1.0f32, vec![1u64, 5, 900]),
            (-1.0f32, vec![]),
            (1.0f32, vec![42]),
        ];
        let payload = encode_row_batch(&rows);
        let back = decode_row_batch(&payload).unwrap();
        assert_eq!(back.len(), rows.len());
        for ((la, ra), (lb, rb)) in rows.iter().zip(&back) {
            assert_eq!(la.to_bits(), lb.to_bits());
            assert_eq!(ra, rb);
        }
        assert!(decode_row_batch(&payload[..payload.len() - 1]).is_err());
        let mut extra = payload.clone();
        extra.push(0);
        assert!(decode_row_batch(&extra).is_err());
        assert_eq!(decode_row_batch(&encode_row_batch(&[])).unwrap(), vec![]);
        assert_eq!(decode_row_batch_ack(&encode_row_batch_ack(7)).unwrap(), 7);
        assert!(decode_row_batch_ack(&[1, 2]).is_err());
    }

    #[test]
    fn reload_and_text_codecs() {
        assert_eq!(decode_reload(&encode_reload(None)).unwrap(), None);
        assert_eq!(
            decode_reload(&encode_reload(Some("/m/v2.bbm"))).unwrap(),
            Some("/m/v2.bbm".to_string())
        );
        assert!(decode_reload(&[1, 0, 0]).is_err());
        assert!(decode_reload(&[9, 0, 0, 0, b'x']).is_err());
        assert_eq!(decode_reload_ok(&encode_reload_ok(7)).unwrap(), 7);
        assert!(decode_reload_ok(&[1, 2]).is_err());
        assert_eq!(decode_text(b"{\"a\":1}").unwrap(), "{\"a\":1}");
        assert!(decode_text(&[0xFF, 0xFE]).is_err());
    }
}
