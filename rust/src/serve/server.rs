//! The scoring server: a std-only thread-pool TCP front end over
//! [`predict_artifact`]-equivalent scoring.
//!
//! Architecture (no async, no new deps):
//!
//! * the caller binds the `TcpListener` (tests bind port 0 and read the
//!   chosen address back) and calls [`serve`], which blocks until stopped;
//! * the accept loop runs nonblocking, polling the stop flag between
//!   accepts, and hands whole connections to a fixed pool of workers over
//!   a bounded channel — one connection is owned by one worker at a time,
//!   frames on it are handled strictly in order;
//! * each worker owns one [`BatchScorer`]: a cached encoder
//!   (`FeatureMap` + `SketchRow` scratch, the PR-2 buffer contract) that
//!   is rebuilt only when a hot swap publishes a model with a different
//!   [`FeatureMapSpec`];
//! * every score request takes **one** [`ModelSlot::load`] snapshot, so
//!   a concurrent swap can never mix models within a response;
//! * graceful shutdown — a `Shutdown` frame, Ctrl-C/SIGTERM (see
//!   [`install_signal_handlers`]), or the caller's stop flag — stops
//!   accepting, lets in-flight connections drain (idle connections close;
//!   half-read frames get a bounded grace period), and returns so the
//!   caller can emit the final stats JSON;
//! * `--watch` adds an mtime-poll thread that hot-swaps the served file
//!   in place when it changes, logging (not crashing) on a bad artifact.
//!
//! [`predict_artifact`]: crate::coordinator::trainer::predict_artifact

use std::io::{self, Read};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::hashing::feature_map::{FeatureMap, FeatureMapSpec};
use crate::hashing::sketch::{SketchMatrix, SketchRow};
use crate::solvers::{LinearModel, SketchView};

use super::protocol::{
    self, decode_reload, decode_score_request, write_frame, FrameHeader, FrameType,
    FRAME_HEADER_LEN,
};
use super::slot::{ModelSlot, ServedModel};
use super::stats::ServeStats;

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("serve: {msg}"))
}

// --------------------------------------------------------- stop signal ----

/// Process-wide stop flag set by SIGINT/SIGTERM. Kept separate from the
/// per-server flag so one Ctrl-C stops every server in the process.
static STOP_REQUESTED: AtomicBool = AtomicBool::new(false);

/// True once SIGINT/SIGTERM arrived (or [`request_stop`] was called).
/// Acquire pairs with the Release stores below: the accept loop that
/// observes the flag also observes whatever the stopper wrote before
/// raising it (handoff, not a gauge).
pub fn stop_requested() -> bool {
    STOP_REQUESTED.load(Ordering::Acquire)
}

/// Programmatic equivalent of Ctrl-C (tests, embedders).
pub fn request_stop() {
    STOP_REQUESTED.store(true, Ordering::Release);
}

/// Route SIGINT (Ctrl-C) and SIGTERM into the stop flag so `serve`
/// drains and reports instead of the process dying mid-request.
///
/// std has no signal API and no libc crate is vendored, so this binds
/// libc's `signal(2)` directly (std already links libc on unix). The
/// handler body is one atomic store — async-signal-safe.
#[cfg(unix)]
pub fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        STOP_REQUESTED.store(true, Ordering::Release);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    // POSIX-fixed numbers on every unix target rust supports.
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

/// No-op off unix: the stop flag still works via `Shutdown` frames.
#[cfg(not(unix))]
pub fn install_signal_handlers() {}

fn should_stop(stop: &AtomicBool) -> bool {
    stop.load(Ordering::Acquire) || stop_requested()
}

// -------------------------------------------------------- batch scorer ----

/// Per-worker scoring state: the encoder for the model generation it last
/// served, rebuilt only when a hot swap changes the [`FeatureMapSpec`].
/// Scoring through it is bit-identical to offline `predict_artifact`:
/// the same `spec.build()` encoder, the same per-row `encode_into`, the
/// same `SketchView` dot product (asserted in `tests/integration_serve.rs`).
pub struct BatchScorer {
    spec: Option<FeatureMapSpec>,
    map: Option<Box<dyn FeatureMap>>,
    scratch: Option<SketchRow>,
}

impl Default for BatchScorer {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchScorer {
    pub fn new() -> Self {
        Self {
            spec: None,
            map: None,
            scratch: None,
        }
    }

    /// Rebuild the cached encoder iff the served spec changed.
    fn ensure_spec(&mut self, spec: &FeatureMapSpec) {
        if self.spec.as_ref() != Some(spec) {
            let map = spec.build();
            self.scratch = Some(SketchRow::new(&map.layout()));
            self.map = Some(map);
            self.spec = Some(spec.clone());
        }
    }

    /// Score one micro-batch against one model snapshot, filling `out`.
    /// Row validation happens here, where the active model (and hence the
    /// input domain) is known: indices must be strictly increasing and
    /// `< spec.dim`, exactly the invariants `SparseBinaryDataset` holds
    /// offline — so a bad row is an `Error` frame, never a worker panic.
    pub fn score_batch(
        &mut self,
        model: &ServedModel,
        rows: &[Vec<u64>],
        out: &mut Vec<f64>,
    ) -> io::Result<()> {
        let spec = &model.artifact.spec;
        for (i, row) in rows.iter().enumerate() {
            if !row.windows(2).all(|w| w[0] < w[1]) {
                return Err(bad(format!(
                    "row {i}: indices must be sorted strictly increasing"
                )));
            }
            if let Some(&max) = row.last() {
                if max >= spec.dim {
                    return Err(bad(format!(
                        "row {i}: index {max} outside the model's input domain {}",
                        spec.dim
                    )));
                }
            }
        }
        self.ensure_spec(spec);
        let (Some(map), Some(scratch)) = (self.map.as_deref(), self.scratch.as_mut()) else {
            return Err(bad("encoder cache empty after ensure_spec".to_string()));
        };
        // One fresh matrix per request (request-scoped, sized up front);
        // the per-row path below reuses the worker's scratch only.
        let mut sk = SketchMatrix::with_capacity(map.layout(), rows.len());
        encode_rows_into(map, rows, scratch, &mut sk);
        let view = SketchView::new(&sk);
        score_view_into(&model.artifact.model, &view, rows.len(), out);
        Ok(())
    }
}

/// Encode a request's rows through the worker's reusable scratch — the
/// per-request encode hot loop (labels are unknown at serving time; the
/// stored 0.0 is never read by scoring).
// bbml-lint: hot-path
fn encode_rows_into(
    map: &dyn FeatureMap,
    rows: &[Vec<u64>],
    scratch: &mut SketchRow,
    sk: &mut SketchMatrix,
) {
    for row in rows {
        map.encode_into(row, scratch.row_mut());
        sk.push_encoded(scratch, 0.0);
    }
}

/// Score every encoded row into `out` — the per-request score hot loop.
// bbml-lint: hot-path
fn score_view_into(model: &LinearModel, view: &SketchView<'_>, n: usize, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(n);
    for i in 0..n {
        // bbml-lint: allow(hot-path-transitive) reason: `model` is a
        // `LinearModel`, whose `score` is alloc-free — the call graph's
        // name-union also matches `ScoreClient::score` (the blocking
        // client), which can never be the receiver here.
        out.push(model.score(view, i));
    }
}

// ------------------------------------------------------------- options ----

/// Server tuning knobs.
pub struct ServeOptions {
    /// Worker threads (each owns one connection at a time).
    pub workers: usize,
    /// Poll the served model file's mtime and hot-swap on change.
    pub watch: bool,
    /// Mtime poll cadence.
    pub watch_interval: Duration,
    /// Per-read socket timeout — the granularity at which idle
    /// connections notice the stop flag.
    pub read_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 4,
            watch: false,
            watch_interval: Duration::from_millis(500),
            read_timeout: Duration::from_millis(250),
        }
    }
}

// ------------------------------------------------- interruptible reads ----

/// Extra read-timeout rounds granted to a connection that is mid-frame
/// when the stop flag lands (in-flight requests drain; stalls don't hang
/// shutdown forever).
const SHUTDOWN_GRACE_POLLS: u32 = 8;

/// Fill `buf` from the stream, polling the stop flag on every read
/// timeout. Returns `Ok(false)` when the connection should close without
/// data: clean EOF before any byte of `buf`, or idle (no byte of `buf`
/// yet) when stopping.
fn fill_interruptible(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    clean_at_zero: bool,
) -> io::Result<bool> {
    let mut got = 0usize;
    let mut grace = 0u32;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && clean_at_zero {
                    return Ok(false);
                }
                return Err(bad(format!("EOF after {got} of {} bytes", buf.len())));
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if should_stop(stop) {
                    if got == 0 && clean_at_zero {
                        return Ok(false);
                    }
                    grace += 1;
                    if grace > SHUTDOWN_GRACE_POLLS {
                        return Err(bad(
                            "connection stalled mid-frame during shutdown".to_string(),
                        ));
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame, returning `Ok(None)` when the connection closed
/// cleanly (EOF at a frame boundary, or idle at shutdown).
fn read_frame_interruptible(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<(FrameType, Vec<u8>)>> {
    let mut head = [0u8; FRAME_HEADER_LEN];
    if !fill_interruptible(stream, &mut head, stop, true)? {
        return Ok(None);
    }
    let header = FrameHeader::decode(&head)?;
    let mut payload = vec![0u8; header.payload_len as usize];
    fill_interruptible(stream, &mut payload, stop, false)?;
    header.verify_payload(&payload)?;
    Ok(Some((header.frame_type()?, payload)))
}

// -------------------------------------------------------------- server ----

/// Run the scoring server on an already-bound listener until stopped (by
/// a `Shutdown` frame, a signal, or `stop`). Blocks; returns once every
/// worker has drained. The caller reads the final gauges from `stats`
/// afterwards.
pub fn serve(
    listener: TcpListener,
    slot: Arc<ModelSlot>,
    stats: Arc<ServeStats>,
    opt: &ServeOptions,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    let workers = opt.workers.max(1);
    listener.set_nonblocking(true)?;
    let (tx, rx) = sync_channel::<TcpStream>(workers * 2);
    let rx = Arc::new(Mutex::new(rx));

    std::thread::scope(|s| -> io::Result<()> {
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            let slot = Arc::clone(&slot);
            let stats = Arc::clone(&stats);
            let stop = Arc::clone(&stop);
            let read_timeout = opt.read_timeout;
            s.spawn(move || worker_loop(w, &rx, &slot, &stats, &stop, read_timeout));
        }
        if opt.watch {
            let slot = Arc::clone(&slot);
            let stop = Arc::clone(&stop);
            let interval = opt.watch_interval;
            s.spawn(move || watch_loop(&slot, &stop, interval));
        }

        while !should_stop(&stop) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    if tx.send(stream).is_err() {
                        break; // every worker exited — nothing can serve
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("serve: accept error: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        // Stop accepting; closing the channel lets workers drain queued
        // connections and exit. scope joins them (and the watcher, which
        // polls the same stop flag) before returning.
        drop(tx);
        Ok(())
    })
}

/// One worker: pull whole connections off the queue, serve them
/// frame-by-frame until EOF / stop, repeat until the queue closes.
fn worker_loop(
    worker: usize,
    rx: &Mutex<Receiver<TcpStream>>,
    slot: &ModelSlot,
    stats: &ServeStats,
    stop: &AtomicBool,
    read_timeout: Duration,
) {
    let mut scorer = BatchScorer::new();
    loop {
        let next = {
            // bbml-lint: allow(no-unwrap) reason: lock poisoning is a
            // propagated panic from another worker, not an input error;
            // recover the receiver and keep draining
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            // bbml-lint: allow(lock-discipline) reason: blocking on recv
            // under the rx mutex IS the work-distribution design — std's
            // Receiver is single-consumer, so the mutex is what makes it
            // multi-consumer; the guard protects nothing but the recv
            // itself and is dropped before the connection is served.
            guard.recv()
        };
        let Ok(stream) = next else { return }; // channel closed: drain done
        if let Err(e) = handle_connection(stream, slot, stats, stop, &mut scorer, read_timeout)
        {
            stats.count_error();
            eprintln!("serve: worker {worker}: connection error: {e}");
        }
    }
}

/// Serve one connection until clean close. Malformed *payloads* get an
/// `Error` frame and the connection lives on; a broken *stream* (bad
/// frame header, socket error) is propagated and the connection dropped.
fn handle_connection(
    mut stream: TcpStream,
    slot: &ModelSlot,
    stats: &ServeStats,
    stop: &AtomicBool,
    scorer: &mut BatchScorer,
    read_timeout: Duration,
) -> io::Result<()> {
    stream.set_read_timeout(Some(read_timeout))?;
    let mut scores: Vec<f64> = Vec::new();
    loop {
        let Some((ft, payload)) = read_frame_interruptible(&mut stream, stop)? else {
            return Ok(());
        };
        match ft {
            FrameType::ScoreRequest => {
                let t0 = Instant::now();
                stats.begin_request();
                let outcome = decode_score_request(&payload).and_then(|rows| {
                    // ONE snapshot for the whole request — the no-mixed-
                    // model guarantee under concurrent hot swap.
                    let model = slot.load();
                    scorer.score_batch(&model, &rows, &mut scores)?;
                    Ok((model.crc32, rows.len()))
                });
                match outcome {
                    Ok((crc, n_rows)) => {
                        let body = protocol::encode_score_response(crc, &scores);
                        write_frame(&mut stream, FrameType::ScoreResponse, &body)?;
                        stats.end_request(n_rows, t0.elapsed());
                    }
                    Err(e) => {
                        stats.abort_request();
                        write_frame(&mut stream, FrameType::Error, e.to_string().as_bytes())?;
                    }
                }
            }
            FrameType::Reload => {
                let outcome = decode_reload(&payload)
                    .and_then(|path| slot.reload_from(path.as_deref().map(std::path::Path::new)));
                match outcome {
                    Ok(crc) => {
                        println!("serve: hot-swapped model (weights_crc32 {crc})");
                        let body = protocol::encode_reload_ok(crc);
                        write_frame(&mut stream, FrameType::ReloadOk, &body)?;
                    }
                    Err(e) => {
                        stats.count_error();
                        write_frame(&mut stream, FrameType::Error, e.to_string().as_bytes())?;
                    }
                }
            }
            FrameType::Stats => {
                let body = stats.to_json(slot.swap_count(), stats.in_flight());
                write_frame(&mut stream, FrameType::StatsResponse, body.as_bytes())?;
            }
            FrameType::Shutdown => {
                // Release pairs with the accept/read loops' Acquire loads
                // (handoff: "this server is shutting down").
                stop.store(true, Ordering::Release);
                write_frame(&mut stream, FrameType::ShutdownOk, b"")?;
                return Ok(());
            }
            other => {
                // Server-bound streams never carry response frames.
                stats.count_error();
                let msg = format!("unexpected frame {other:?} on a server connection");
                write_frame(&mut stream, FrameType::Error, msg.as_bytes())?;
            }
        }
    }
}

/// The `--watch` thread: poll the served file's mtime; on change, reload
/// in place. A half-written or incompatible file is logged and retried on
/// the next tick — the slot's validation guarantees the live model stays.
fn watch_loop(slot: &ModelSlot, stop: &AtomicBool, interval: Duration) {
    let tick = Duration::from_millis(50).min(interval);
    let mut since_poll = Duration::ZERO;
    while !should_stop(stop) {
        std::thread::sleep(tick);
        since_poll += tick;
        if since_poll < interval {
            continue;
        }
        since_poll = Duration::ZERO;
        if slot.source_changed() {
            match slot.reload_from(None) {
                Ok(crc) => println!("serve: watch hot-swapped model (weights_crc32 {crc})"),
                Err(e) => eprintln!("serve: watch reload failed (keeping live model): {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::feature_map::Scheme;
    use crate::rng::Xoshiro256;
    use crate::store::ModelArtifact;
    use std::path::PathBuf;

    fn served(scheme: Scheme, k: usize, seed: u64) -> ServedModel {
        let spec = FeatureMapSpec::new(scheme, 1 << 20, k, 4, seed);
        let n = spec.layout().train_dim();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let w: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
        let artifact = ModelArtifact::new(
            spec,
            LinearModel {
                w,
                iters: 1,
                objective: 0.0,
            },
        )
        .unwrap();
        let crc32 = crate::coordinator::report::weights_crc32(&artifact.model.w);
        ServedModel {
            artifact,
            crc32,
            source: PathBuf::from("/dev/null"),
            mtime: None,
        }
    }

    #[test]
    fn batch_scorer_is_deterministic_and_validates_rows() {
        let model = served(Scheme::Bbit, 16, 7);
        let mut scorer = BatchScorer::new();
        let rows = vec![vec![3u64, 99, 4000], vec![17, 170_000]];
        let mut a = Vec::new();
        let mut b = Vec::new();
        scorer.score_batch(&model, &rows, &mut a).unwrap();
        scorer.score_batch(&model, &rows, &mut b).unwrap();
        assert_eq!(a.len(), 2);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "same scorer, same rows, same bits");

        // Unsorted, duplicate, and out-of-domain rows are InvalidData.
        for rows in [
            vec![vec![5u64, 3]],
            vec![vec![5u64, 5]],
            vec![vec![1u64 << 20]],
        ] {
            let err = scorer.score_batch(&model, &rows, &mut a).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{rows:?}");
        }
        // Empty rows and empty batches are fine.
        scorer.score_batch(&model, &[vec![]], &mut a).unwrap();
        assert_eq!(a.len(), 1);
        scorer.score_batch(&model, &[], &mut a).unwrap();
        assert!(a.is_empty());
    }

    #[test]
    fn batch_scorer_rebuilds_encoder_on_spec_change_only() {
        let m8 = served(Scheme::Bbit, 8, 1);
        let m16 = served(Scheme::Bbit, 16, 1);
        let mut scorer = BatchScorer::new();
        let rows = vec![vec![10u64, 20, 30]];
        let mut out = Vec::new();
        scorer.score_batch(&m8, &rows, &mut out).unwrap();
        assert_eq!(scorer.spec.as_ref().map(|s| s.k), Some(8));
        scorer.score_batch(&m16, &rows, &mut out).unwrap();
        assert_eq!(scorer.spec.as_ref().map(|s| s.k), Some(16));
        // Dense schemes flow through the same cache.
        let vw = served(Scheme::Vw, 12, 2);
        scorer.score_batch(&vw, &rows, &mut out).unwrap();
        assert_eq!(scorer.spec.as_ref().map(|s| s.scheme), Some(Scheme::Vw));
    }

    #[test]
    fn stop_flag_helpers() {
        let local = AtomicBool::new(false);
        assert!(!should_stop(&local));
        local.store(true, Ordering::Relaxed);
        assert!(should_stop(&local));
        // The global flag feeds the same predicate (reset afterwards so
        // other tests in this process see a quiet flag).
        let fresh = AtomicBool::new(false);
        request_stop();
        assert!(should_stop(&fresh));
        STOP_REQUESTED.store(false, Ordering::Relaxed);
    }
}
