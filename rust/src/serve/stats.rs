//! [`ServeStats`] — the serving gauges: request/row/error counters, queue
//! depth, and a capped latency reservoir that yields p50/p95/p99 in the
//! same index-rounding convention as [`crate::benchkit::Stats`], so the
//! report numbers and the `bench_serving` numbers are comparable.
//!
//! Counters are plain atomics (workers bump them lock-free); only the
//! latency reservoir takes a mutex, once per request, to push one `u64`.
//! Gauges are emitted two ways from the same entries: the final
//! `serve_report.json` (via [`report::write_json_object`]) and the
//! `Stats` control frame's inline JSON.
//!
//! Every atomic here is a **gauge** in the R8 (`atomic-ordering`) sense
//! and uses `Ordering::Relaxed` deliberately: no thread acts on these
//! values — they only feed monitoring output, where a count that trails
//! reality by a few operations is harmless. Nothing is published
//! *through* them (the request/response data flows over sockets and the
//! [`super::slot::ModelSlot`] lock, which carry their own ordering), so
//! Acquire/Release here would cost fence traffic on every request and
//! buy nothing. Contrast with the handoff atomics in [`super::server`]
//! (stop flags) and [`super::slot`] (swap counter).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::report;

/// Retained latency samples. Old samples are overwritten ring-style once
/// full, so long-running servers report *recent* tails, not launch-time
/// warmup forever.
const LATENCY_CAP: usize = 16_384;

/// Shared serving gauges (one per server, `Arc`-shared with workers).
pub struct ServeStats {
    started: Instant,
    requests: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    /// Requests currently being scored (decremented on completion).
    in_flight: AtomicU64,
    /// High-water mark of `in_flight` — the queue-depth gauge.
    peak_in_flight: AtomicU64,
    /// Per-request wall latency in µs, ring-buffered.
    latency_us: Mutex<LatencyRing>,
}

struct LatencyRing {
    samples: Vec<u64>,
    next: usize,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            peak_in_flight: AtomicU64::new(0),
            latency_us: Mutex::new(LatencyRing {
                samples: Vec::with_capacity(1024),
                next: 0,
            }),
        }
    }

    /// A request entered scoring. Returns the depth *including* it.
    pub fn begin_request(&self) -> u64 {
        let depth = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_in_flight.fetch_max(depth, Ordering::Relaxed);
        depth
    }

    /// A request finished (scored `rows` rows in `latency`).
    pub fn end_request(&self, rows: usize, latency: Duration) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        // bbml-lint: allow(no-unwrap) reason: lock poisoning is a
        // propagated panic, not an input error; recover and keep counting
        let mut ring = self.latency_us.lock().unwrap_or_else(|e| e.into_inner());
        if ring.samples.len() < LATENCY_CAP {
            ring.samples.push(us);
        } else {
            let at = ring.next;
            ring.samples[at] = us;
            ring.next = (at + 1) % LATENCY_CAP;
        }
    }

    /// A begun request failed before producing scores: leave the
    /// in-flight gauge balanced and count the error.
    pub fn abort_request(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A request failed (protocol error, invalid rows, failed reload…).
    pub fn count_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests being scored right now — the live queue-depth gauge.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn peak_in_flight(&self) -> u64 {
        self.peak_in_flight.load(Ordering::Relaxed)
    }

    /// Latency percentiles over the retained samples, in µs:
    /// `(p50, p95, p99)`. All zero before the first completed request.
    pub fn latency_percentiles_us(&self) -> (u64, u64, u64) {
        // bbml-lint: allow(no-unwrap) reason: lock poisoning is a
        // propagated panic, not an input error; recover and report
        let ring = self.latency_us.lock().unwrap_or_else(|e| e.into_inner());
        if ring.samples.is_empty() {
            return (0, 0, 0);
        }
        let mut sorted = ring.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        // Same nearest-rank rounding as benchkit::Stats::from_samples.
        let pct = |q: f64| sorted[((n - 1) as f64 * q).round() as usize];
        (pct(0.5), pct(0.95), pct(0.99))
    }

    /// The gauges as report entries — one source of truth for both the
    /// final `serve_report.json` and the `Stats` frame. `swap_count` and
    /// `queue_depth` come from the caller (slot / live counter).
    pub fn report_entries(&self, swap_count: u64, queue_depth: u64) -> Vec<(&'static str, String)> {
        let (p50, p95, p99) = self.latency_percentiles_us();
        let uptime = self.started.elapsed().as_secs_f64();
        let rows = self.rows();
        let rows_per_sec = if uptime > 0.0 {
            rows as f64 / uptime
        } else {
            0.0
        };
        vec![
            ("requests", self.requests().to_string()),
            ("rows", rows.to_string()),
            ("errors", self.errors().to_string()),
            ("swap_count", swap_count.to_string()),
            ("queue_depth", queue_depth.to_string()),
            ("peak_queue_depth", self.peak_in_flight().to_string()),
            ("p50_us", p50.to_string()),
            ("p95_us", p95.to_string()),
            ("p99_us", p99.to_string()),
            ("rows_per_sec", format!("{rows_per_sec:.3}")),
            ("uptime_secs", format!("{uptime:.6}")),
        ]
    }

    /// The gauges as one inline JSON object (the `StatsResponse` payload).
    pub fn to_json(&self, swap_count: u64, queue_depth: u64) -> String {
        let entries = self.report_entries(swap_count, queue_depth);
        let mut out = String::with_capacity(entries.len() * 24);
        out.push('{');
        for (idx, (key, value)) in entries.iter().enumerate() {
            if idx > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("{}: {value}", report::json_string(key)));
        }
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let s = ServeStats::new();
        assert_eq!(s.latency_percentiles_us(), (0, 0, 0));
        let d1 = s.begin_request();
        let d2 = s.begin_request();
        assert_eq!((d1, d2), (1, 2));
        assert_eq!(s.in_flight(), 2);
        s.end_request(10, Duration::from_micros(100));
        s.end_request(20, Duration::from_micros(300));
        s.count_error();
        assert_eq!(s.in_flight(), 0);
        s.begin_request();
        s.abort_request();
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.errors(), 2);
        assert_eq!(s.requests(), 2);
        assert_eq!(s.rows(), 30);
        assert_eq!(s.peak_in_flight(), 2);
        let (p50, p95, p99) = s.latency_percentiles_us();
        assert!((100..=300).contains(&p50));
        assert_eq!((p95, p99), (300, 300));
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn ring_caps_and_keeps_recent_samples() {
        let s = ServeStats::new();
        for i in 0..(LATENCY_CAP + 10) {
            s.begin_request();
            s.end_request(1, Duration::from_micros(i as u64));
        }
        let ring = s.latency_us.lock().unwrap();
        assert_eq!(ring.samples.len(), LATENCY_CAP);
        // The overwritten head now holds the newest samples.
        assert_eq!(ring.samples[0], LATENCY_CAP as u64);
        assert_eq!(ring.next, 10);
    }

    #[test]
    fn json_gauges_parse_by_eye() {
        let s = ServeStats::new();
        s.begin_request();
        s.end_request(5, Duration::from_micros(42));
        let j = s.to_json(3, 1);
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        for key in [
            "\"requests\": 1",
            "\"rows\": 5",
            "\"swap_count\": 3",
            "\"queue_depth\": 1",
            "\"p50_us\": 42",
            "\"p99_us\": 42",
            "\"rows_per_sec\":",
            "\"uptime_secs\":",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        let entries = s.report_entries(0, 0);
        assert_eq!(entries.len(), 11);
    }
}
