//! [`ModelSlot`] — the atomically hot-swappable published model.
//!
//! The serving invariant: a request is scored **entirely** by one model.
//! Workers take one `Arc<ServedModel>` snapshot per request
//! ([`ModelSlot::load`]) and never touch the slot again until the
//! response is written, so a concurrent [`ModelSlot::reload_from`] can
//! swap the published artifact without a torn read — in-flight requests
//! finish on the model they started with, new requests see the new one,
//! and a mixed-model response is structurally impossible (asserted under
//! hammering in `tests/integration_serve.rs`).
//!
//! Swap validation: the incoming artifact must keep the live requests'
//! *input contract* — same scheme and same input domain `dim` — because
//! clients encode nothing; they ship raw indices that must stay valid
//! against whatever model is active. Width parameters (`k`, `b`,
//! `buckets`, `seed`) may change freely: workers compare the snapshot's
//! [`FeatureMapSpec`] against their cached encoder and rebuild it when a
//! retrained model differs. A failed validation leaves the slot untouched.
//!
//! # The snapshot-pointer handshake (`serve --watch` × `online-train`)
//!
//! The online trainer publishes snapshots as immutable
//! `model-<seq>.model` files plus a tiny `latest.model` **pointer**
//! ([`crate::store::ModelPointer`]), each renamed into place atomically —
//! artifact first, pointer second (the publisher half lives in
//! [`crate::online::publish`]; the byte format in [`crate::store`]).
//! This loader completes the handshake: [`ServedModel::load`] sniffs the
//! `BBMPTR` magic, resolves the pointer's sibling target, and **refuses
//! the swap unless the target exists and its framed payload CRC matches
//! the one the pointer recorded** — so a reload can never serve a
//! half-written, damaged, or mismatched file; the slot keeps the previous
//! model on any failure and the watch simply retries next poll. The
//! served `source` (and the watched mtime) stay on the *pointer* file:
//! targets are immutable history, the pointer is the only thing that
//! moves, and re-resolving it is exactly what a reload should do.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::SystemTime;

use crate::coordinator::report::weights_crc32;
use crate::store::{is_model_pointer, model_payload_crc32, ModelArtifact, ModelPointer};

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("model slot: {msg}"))
}

/// One published model: the artifact plus everything the serving layer
/// reports about it (fingerprint, source path, file mtime for the watch).
pub struct ServedModel {
    /// The self-describing artifact being served.
    pub artifact: ModelArtifact,
    /// `weights_crc32` fingerprint, computed once at publish time and
    /// stamped on every score response.
    pub crc32: u32,
    /// The file this model was loaded from (reload / watch target).
    pub source: PathBuf,
    /// Source-file modification time at load, when the filesystem
    /// reports one — the mtime watch's change detector.
    pub mtime: Option<SystemTime>,
}

impl ServedModel {
    /// Load an artifact file — or a snapshot **pointer** file — into a
    /// publishable model.
    ///
    /// A pointer (sniffed by its `BBMPTR` magic) is resolved to its
    /// sibling target, which must exist and whose framed payload CRC must
    /// equal the one the pointer recorded — the reader half of the
    /// publish handshake (module docs). Any violation is an error and
    /// loads nothing; the recorded `source`/`mtime` stay on the pointer
    /// file so the mtime watch follows pointer swaps, not the immutable
    /// snapshot files behind them.
    pub fn load(path: &Path) -> io::Result<Self> {
        let artifact_path = if is_model_pointer(path) {
            let ptr = ModelPointer::load(path)?;
            let target = ptr.target(path);
            let got = model_payload_crc32(&target)?;
            if got != ptr.model_crc32 {
                return Err(bad(format!(
                    "pointer {} records payload CRC {:#010x} but its target \
                     {} has {got:#010x} — refusing the swap",
                    path.display(),
                    ptr.model_crc32,
                    target.display()
                )));
            }
            target
        } else {
            path.to_path_buf()
        };
        let artifact = ModelArtifact::load(&artifact_path)?;
        let crc32 = weights_crc32(&artifact.model.w);
        let mtime = std::fs::metadata(path).and_then(|m| m.modified()).ok();
        Ok(Self {
            artifact,
            crc32,
            source: path.to_path_buf(),
            mtime,
        })
    }
}

/// The slot itself: an `RwLock<Arc<…>>` in lieu of an external arc-swap
/// crate. Readers clone the `Arc` under a momentary read lock (two atomic
/// ops, no allocation); the write lock is held only for the pointer swap
/// itself — artifact loading and validation happen outside it.
pub struct ModelSlot {
    inner: RwLock<Arc<ServedModel>>,
    /// Completed-swap counter. Classified as a handoff, not a gauge: the
    /// serving tests (and any operator polling `swap_count`) use "count
    /// advanced" as proof the new model is visible, so the increment must
    /// publish the swap it counts.
    // bbml-lint: atomic(handoff)
    swaps: AtomicU64,
}

impl ModelSlot {
    /// Publish the initial model.
    pub fn new(model: ServedModel) -> Self {
        Self {
            inner: RwLock::new(Arc::new(model)),
            swaps: AtomicU64::new(0),
        }
    }

    /// Snapshot the currently published model. The returned `Arc` keeps
    /// that model alive for the whole request even if a swap lands
    /// mid-flight.
    pub fn load(&self) -> Arc<ServedModel> {
        // bbml-lint: allow(no-unwrap) reason: lock poisoning is a
        // propagated panic from another thread, not an input error;
        // recover the guard and keep serving (repo-wide poison idiom)
        let guard = self.inner.read().unwrap_or_else(|e| e.into_inner());
        Arc::clone(&guard)
    }

    /// Completed swaps so far. Acquire pairs with the AcqRel increment in
    /// [`ModelSlot::reload_from`]: an observer that sees count N also
    /// sees the Nth published model.
    pub fn swap_count(&self) -> u64 {
        self.swaps.load(Ordering::Acquire)
    }

    /// Load a new artifact and atomically publish it. `path` of `None`
    /// re-reads the currently served source file (the `reload` control
    /// frame's default, and what the mtime watch triggers).
    ///
    /// Validates scheme + input-domain compatibility against the live
    /// model *before* committing; on any error the slot is unchanged and
    /// in-flight requests are unaffected. Returns the new fingerprint.
    pub fn reload_from(&self, path: Option<&Path>) -> io::Result<u32> {
        let current = self.load();
        let path = path.unwrap_or(&current.source);
        let incoming = ServedModel::load(path)?;
        let (old, new) = (&current.artifact.spec, &incoming.artifact.spec);
        if new.scheme != old.scheme {
            return Err(bad(format!(
                "refusing swap: live model serves scheme '{}', {} records '{}'",
                old.scheme,
                path.display(),
                new.scheme
            )));
        }
        if new.dim != old.dim {
            return Err(bad(format!(
                "refusing swap: live input domain is {}, {} records {} — \
                 clients' raw indices would silently change meaning",
                old.dim,
                path.display(),
                new.dim
            )));
        }
        let crc = incoming.crc32;
        {
            // bbml-lint: allow(no-unwrap) reason: lock poisoning is a
            // propagated panic, not an input error; recover and swap
            let mut guard = self.inner.write().unwrap_or_else(|e| e.into_inner());
            *guard = Arc::new(incoming);
        }
        // AcqRel: the increment happens-after the pointer swap above and
        // publishes it to whoever reads the count (see `swap_count`).
        self.swaps.fetch_add(1, Ordering::AcqRel);
        Ok(crc)
    }

    /// True when the served source file's mtime differs from the one
    /// recorded at publish — the mtime watch's poll predicate. Errors
    /// reading metadata (file mid-replace) read as "unchanged".
    pub fn source_changed(&self) -> bool {
        let current = self.load();
        match std::fs::metadata(&current.source).and_then(|m| m.modified()) {
            Ok(mtime) => current.mtime.map(|old| mtime != old).unwrap_or(false),
            Err(_) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::feature_map::{FeatureMapSpec, Scheme};
    use crate::rng::Xoshiro256;
    use crate::solvers::LinearModel;

    fn artifact(scheme: Scheme, dim: u64, k: usize, seed: u64) -> ModelArtifact {
        let spec = FeatureMapSpec::new(scheme, dim, k, 4, seed);
        let n = spec.layout().train_dim();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let w: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
        ModelArtifact::new(
            spec,
            LinearModel {
                w,
                iters: 1,
                objective: 0.0,
            },
        )
        .unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bbml_slot_{}_{}", name, std::process::id()))
    }

    #[test]
    fn publish_swap_and_count() {
        let p1 = tmp("m1.bbm");
        let p2 = tmp("m2.bbm");
        artifact(Scheme::Bbit, 1 << 20, 8, 1).save(&p1).unwrap();
        artifact(Scheme::Bbit, 1 << 20, 16, 2).save(&p2).unwrap();
        let slot = ModelSlot::new(ServedModel::load(&p1).unwrap());
        let first = slot.load();
        assert_eq!(slot.swap_count(), 0);

        let crc2 = slot.reload_from(Some(&p2)).unwrap();
        assert_eq!(slot.swap_count(), 1);
        let second = slot.load();
        assert_eq!(second.crc32, crc2);
        assert_ne!(first.crc32, second.crc32);
        // Differing k is fine (retrained model); the old snapshot is
        // still fully usable — that's the no-torn-read guarantee.
        assert_eq!(first.artifact.spec.k, 8);
        assert_eq!(second.artifact.spec.k, 16);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn incompatible_swap_is_rejected_and_slot_unchanged() {
        let p1 = tmp("c1.bbm");
        let p_scheme = tmp("c2.bbm");
        let p_dim = tmp("c3.bbm");
        artifact(Scheme::Bbit, 1 << 20, 8, 1).save(&p1).unwrap();
        artifact(Scheme::Vw, 1 << 20, 8, 2).save(&p_scheme).unwrap();
        artifact(Scheme::Bbit, 1 << 21, 8, 3).save(&p_dim).unwrap();
        let slot = ModelSlot::new(ServedModel::load(&p1).unwrap());
        let before = slot.load().crc32;

        let err = slot.reload_from(Some(&p_scheme)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("scheme"), "{err}");
        let err = slot.reload_from(Some(&p_dim)).unwrap_err();
        assert!(err.to_string().contains("domain"), "{err}");
        // Missing file: also refused, slot untouched.
        assert!(slot.reload_from(Some(Path::new("/no/such.bbm"))).is_err());

        assert_eq!(slot.load().crc32, before);
        assert_eq!(slot.swap_count(), 0);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p_scheme).ok();
        std::fs::remove_file(&p_dim).ok();
    }

    #[test]
    fn pointer_load_resolves_target_and_follows_pointer_swaps() {
        use crate::online::publish::SnapshotPublisher;
        let dir = tmp("ptr_dir");
        std::fs::remove_dir_all(&dir).ok();
        let mut publisher = SnapshotPublisher::new(&dir, 0).unwrap();
        publisher.publish(&artifact(Scheme::Bbit, 1 << 20, 8, 1)).unwrap();
        let ptr_path = publisher.pointer_path();

        let served = ServedModel::load(&ptr_path).unwrap();
        // The watch follows the pointer file, not the snapshot behind it.
        assert_eq!(served.source, ptr_path);
        let first = served.crc32;
        let slot = ModelSlot::new(served);

        // Publish a retrained snapshot; the pointer now names seq 1 and
        // a source-path reload (what the mtime watch issues) swaps to it.
        publisher.publish(&artifact(Scheme::Bbit, 1 << 20, 16, 2)).unwrap();
        let crc = slot.reload_from(None).unwrap();
        assert_ne!(crc, first);
        assert_eq!(slot.load().crc32, crc);
        assert_eq!(slot.load().artifact.spec.k, 16);
        assert_eq!(slot.swap_count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn damaged_pointer_pairs_are_refused_and_slot_unchanged() {
        use crate::online::publish::SnapshotPublisher;
        use crate::store::ModelPointer;
        let dir = tmp("ptr_bad");
        std::fs::remove_dir_all(&dir).ok();
        let mut publisher = SnapshotPublisher::new(&dir, 0).unwrap();
        let snap = publisher.publish(&artifact(Scheme::Bbit, 1 << 20, 8, 1)).unwrap();
        let ptr_path = publisher.pointer_path();
        let slot = ModelSlot::new(ServedModel::load(&ptr_path).unwrap());
        let before = slot.load().crc32;

        // Pointer whose recorded CRC disagrees with the on-disk target:
        // mid-publish damage — the swap must be refused.
        ModelPointer {
            seq: 1,
            model_crc32: snap.model_crc32 ^ 0xdead_beef,
            name: "model-00000.model".to_string(),
        }
        .save(&ptr_path)
        .unwrap();
        let err = slot.reload_from(None).unwrap_err();
        assert!(err.to_string().contains("refusing the swap"), "{err}");

        // Pointer naming a target that does not exist yet: also refused.
        ModelPointer {
            seq: 2,
            model_crc32: snap.model_crc32,
            name: "model-00099.model".to_string(),
        }
        .save(&ptr_path)
        .unwrap();
        assert!(slot.reload_from(None).is_err());

        assert_eq!(slot.load().crc32, before, "slot keeps the old model");
        assert_eq!(slot.swap_count(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reload_none_rereads_the_source_path() {
        let p = tmp("rr.bbm");
        artifact(Scheme::Bbit, 1 << 20, 8, 1).save(&p).unwrap();
        let slot = ModelSlot::new(ServedModel::load(&p).unwrap());
        // Overwrite the file in place with a retrained model.
        artifact(Scheme::Bbit, 1 << 20, 8, 99).save(&p).unwrap();
        let crc = slot.reload_from(None).unwrap();
        assert_eq!(slot.load().crc32, crc);
        assert_eq!(slot.swap_count(), 1);
        std::fs::remove_file(&p).ok();
    }
}
