//! [`ScoreClient`] — a minimal blocking client for the serve protocol,
//! used by the `score` CLI verb, the integration tests, and
//! `bench_serving`. One client owns one TCP connection; requests on it
//! are strictly sequential (frame out, frame back), which is exactly the
//! protocol's per-connection contract.

use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{
    decode_reload_ok, decode_score_response, decode_text, encode_reload, encode_score_request,
    read_frame, write_frame, FrameType,
};

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("serve client: {msg}"))
}

/// A blocking scoring-service client over one TCP connection.
pub struct ScoreClient {
    stream: TcpStream,
}

impl ScoreClient {
    /// Connect to a running server. Reads get a generous timeout so a
    /// hung server surfaces as `TimedOut` instead of blocking forever.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(Self { stream })
    }

    /// Send one frame and block for the server's reply.
    fn roundtrip(&mut self, ft: FrameType, payload: &[u8]) -> io::Result<(FrameType, Vec<u8>)> {
        write_frame(&mut self.stream, ft, payload)?;
        match read_frame(&mut self.stream)? {
            Some(reply) => Ok(reply),
            None => Err(bad("server closed the connection mid-request".to_string())),
        }
    }

    /// Turn an `Error` frame into an `io::Error`, anything else through.
    fn expect(
        reply: (FrameType, Vec<u8>),
        want: FrameType,
    ) -> io::Result<Vec<u8>> {
        let (ft, payload) = reply;
        if ft == FrameType::Error {
            return Err(bad(format!("server error: {}", decode_text(&payload)?)));
        }
        if ft != want {
            return Err(bad(format!("expected {want:?} reply, got {ft:?}")));
        }
        Ok(payload)
    }

    /// Score a micro-batch of raw sparse rows (sorted unique indices).
    /// Returns the serving model's `weights_crc32` fingerprint and one
    /// f64 score per row, bit-identical to offline `predict_artifact`.
    pub fn score(&mut self, rows: &[Vec<u64>]) -> io::Result<(u32, Vec<f64>)> {
        let body = encode_score_request(rows);
        let reply = self.roundtrip(FrameType::ScoreRequest, &body)?;
        decode_score_response(&Self::expect(reply, FrameType::ScoreResponse)?)
    }

    /// Hot-swap the served model (`None` = re-read the current source
    /// file). Returns the newly published model's fingerprint.
    pub fn reload(&mut self, path: Option<&str>) -> io::Result<u32> {
        let body = encode_reload(path);
        let reply = self.roundtrip(FrameType::Reload, &body)?;
        decode_reload_ok(&Self::expect(reply, FrameType::ReloadOk)?)
    }

    /// Fetch the live gauges as a JSON object string.
    pub fn stats(&mut self) -> io::Result<String> {
        let reply = self.roundtrip(FrameType::Stats, b"")?;
        decode_text(&Self::expect(reply, FrameType::StatsResponse)?)
    }

    /// Ask the server to shut down gracefully (stop accepting, drain,
    /// emit the final report). Consumes the client — the server closes
    /// this connection after acknowledging.
    pub fn shutdown(mut self) -> io::Result<()> {
        let reply = self.roundtrip(FrameType::Shutdown, b"")?;
        Self::expect(reply, FrameType::ShutdownOk)?;
        Ok(())
    }
}
