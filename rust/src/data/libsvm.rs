//! LIBSVM text format I/O (the interchange format of the paper's webspam
//! experiments), with transparent gzip support.
//!
//! Format, one example per line:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based in files and converted to 0-based internally. The
//! paper's data are binary, so on read any non-zero value becomes a set
//! member, and on write every member is emitted as `idx:1`.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use flate2::read::GzDecoder;
use flate2::write::GzEncoder;

use super::sparse::{SparseBinaryDataset, SparseBinaryVec};

/// Errors from LIBSVM parsing.
#[derive(Debug)]
pub enum LibsvmError {
    Io(io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "io: {e}"),
            LibsvmError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            LibsvmError::Parse { .. } => None,
        }
    }
}

impl From<io::Error> for LibsvmError {
    fn from(e: io::Error) -> Self {
        LibsvmError::Io(e)
    }
}

/// Parse one LIBSVM line (shared with the online row sources): `Ok(None)`
/// for blank/comment lines, otherwise the normalized ±1 label and the
/// 0-based indices of the nonzero features, in file order.
pub(crate) fn parse_line(
    line: &str,
    lineno: usize,
) -> Result<Option<(f32, Vec<u64>)>, LibsvmError> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label_tok = parts.next().ok_or_else(|| LibsvmError::Parse {
        line: lineno,
        msg: "missing label".into(),
    })?;
    let label: f32 = label_tok.parse().map_err(|_| LibsvmError::Parse {
        line: lineno,
        msg: format!("bad label '{label_tok}'"),
    })?;
    let label = if label > 0.0 { 1.0 } else { -1.0 };
    let mut idxs = Vec::new();
    for tok in parts {
        let (i_str, v_str) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
            line: lineno,
            msg: format!("bad feature token '{tok}'"),
        })?;
        let idx: u64 = i_str.parse().map_err(|_| LibsvmError::Parse {
            line: lineno,
            msg: format!("bad index '{i_str}'"),
        })?;
        if idx == 0 {
            return Err(LibsvmError::Parse {
                line: lineno,
                msg: "LIBSVM indices are 1-based; got 0".into(),
            });
        }
        let val: f64 = v_str.parse().map_err(|_| LibsvmError::Parse {
            line: lineno,
            msg: format!("bad value '{v_str}'"),
        })?;
        if val != 0.0 {
            idxs.push(idx - 1); // 0-based internally
        }
    }
    Ok(Some((label, idxs)))
}

/// Read a LIBSVM file (gzip if the path ends in `.gz`). `dim` of the result
/// is `max_index + 1` unless `dim_hint` is larger.
pub fn read_libsvm(path: &Path, dim_hint: Option<u64>) -> Result<SparseBinaryDataset, LibsvmError> {
    let file = File::open(path)?;
    let reader: Box<dyn BufRead> = if path.extension().is_some_and(|e| e == "gz") {
        Box::new(BufReader::new(GzDecoder::new(file)))
    } else {
        Box::new(BufReader::new(file))
    };
    read_libsvm_from(reader, dim_hint)
}

/// Read from any buffered reader (for tests and in-memory use).
pub fn read_libsvm_from<R: BufRead>(
    reader: R,
    dim_hint: Option<u64>,
) -> Result<SparseBinaryDataset, LibsvmError> {
    let mut rows: Vec<(f32, Vec<u64>)> = Vec::new();
    let mut max_idx: u64 = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some((label, idxs)) = parse_line(&line, lineno + 1)? {
            if let Some(&m) = idxs.iter().max() {
                max_idx = max_idx.max(m);
            }
            rows.push((label, idxs));
        }
    }
    let dim = dim_hint.unwrap_or(0).max(max_idx + 1);
    let mut ds = SparseBinaryDataset::new(dim);
    for (label, idxs) in rows {
        ds.push(SparseBinaryVec::from_indices(idxs), label);
    }
    Ok(ds)
}

/// Write a dataset in LIBSVM format (gzip if the path ends in `.gz`).
pub fn write_libsvm(ds: &SparseBinaryDataset, path: &Path) -> Result<(), LibsvmError> {
    let file = File::create(path)?;
    if path.extension().is_some_and(|e| e == "gz") {
        let mut w = BufWriter::new(GzEncoder::new(file, flate2::Compression::fast()));
        write_libsvm_to(ds, &mut w)?;
        w.flush()?;
    } else {
        let mut w = BufWriter::new(file);
        write_libsvm_to(ds, &mut w)?;
        w.flush()?;
    }
    Ok(())
}

fn write_libsvm_to<W: Write>(ds: &SparseBinaryDataset, w: &mut W) -> io::Result<()> {
    for (row, label) in ds.iter() {
        if label > 0.0 {
            write!(w, "+1")?;
        } else {
            write!(w, "-1")?;
        }
        for &idx in row {
            write!(w, " {}:1", idx + 1)?; // 1-based on disk
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic_lines() {
        let text = "+1 3:1 7:1 10:1\n-1 1:1\n\n# comment\n+1 2:0 4:1\n";
        let ds = read_libsvm_from(Cursor::new(text), None).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.row(0), &[2, 6, 9]); // 0-based
        assert_eq!(ds.label(0), 1.0);
        assert_eq!(ds.row(1), &[0]);
        assert_eq!(ds.label(1), -1.0);
        // zero value dropped (binary semantics)
        assert_eq!(ds.row(2), &[3]);
        assert_eq!(ds.dim(), 10);
    }

    #[test]
    fn dim_hint_respected() {
        let ds = read_libsvm_from(Cursor::new("+1 1:1\n"), Some(1000)).unwrap();
        assert_eq!(ds.dim(), 1000);
    }

    #[test]
    fn rejects_zero_index() {
        let err = read_libsvm_from(Cursor::new("+1 0:1\n"), None).unwrap_err();
        assert!(matches!(err, LibsvmError::Parse { line: 1, .. }));
    }

    #[test]
    fn rejects_malformed_token() {
        assert!(read_libsvm_from(Cursor::new("+1 3-1\n"), None).is_err());
        assert!(read_libsvm_from(Cursor::new("abc 3:1\n"), None).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let mut ds = SparseBinaryDataset::new(64);
        ds.push(SparseBinaryVec::from_indices(vec![0, 5, 63]), 1.0);
        ds.push(SparseBinaryVec::from_indices(vec![7]), -1.0);
        let dir = std::env::temp_dir();
        for name in ["bbml_rt.libsvm", "bbml_rt.libsvm.gz"] {
            let path = dir.join(name);
            write_libsvm(&ds, &path).unwrap();
            let back = read_libsvm(&path, Some(64)).unwrap();
            assert_eq!(back.n(), 2);
            assert_eq!(back.row(0), ds.row(0));
            assert_eq!(back.row(1), ds.row(1));
            assert_eq!(back.label(1), -1.0);
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn labels_normalized_to_pm1() {
        let ds = read_libsvm_from(Cursor::new("2 1:1\n0 2:1\n"), None).unwrap();
        assert_eq!(ds.label(0), 1.0);
        assert_eq!(ds.label(1), -1.0);
    }
}
