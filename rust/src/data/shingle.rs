//! w-shingling: turn token streams into binary feature sets (paper §1.1).
//!
//! A *w-shingle* is w contiguous words; the standard search-industry
//! representation hashes each shingle into a dictionary Ω of size D (up to
//! 2^64) and keeps only presence/absence — word-frequency power laws make a
//! shingle very unlikely to repeat within one document, so the binary
//! quantization loses almost nothing (paper §1.1).

use super::sparse::SparseBinaryVec;

/// 64-bit FNV-1a — stable, fast string hashing for shingles.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Shingler configuration.
#[derive(Clone, Debug)]
pub struct Shingler {
    /// Shingle width w (the paper uses w = 3 for webspam, up to 5–7).
    pub w: usize,
    /// Dictionary size D; shingle hashes are reduced mod D.
    pub dim: u64,
}

impl Shingler {
    pub fn new(w: usize, dim: u64) -> Self {
        assert!(w >= 1, "shingle width must be >= 1");
        assert!(dim >= 1);
        Self { w, dim }
    }

    /// Shingle a pre-tokenized document into a sparse binary vector.
    ///
    /// Documents shorter than w yield a single shingle over all tokens.
    pub fn shingle_tokens(&self, tokens: &[&str]) -> SparseBinaryVec {
        if tokens.is_empty() {
            return SparseBinaryVec::from_indices(vec![]);
        }
        let mut idxs = Vec::with_capacity(tokens.len().saturating_sub(self.w) + 1);
        if tokens.len() < self.w {
            idxs.push(self.hash_shingle(tokens));
        } else {
            for win in tokens.windows(self.w) {
                idxs.push(self.hash_shingle(win));
            }
        }
        SparseBinaryVec::from_indices(idxs)
    }

    /// Shingle raw text (ASCII-whitespace tokenization, lowercased).
    pub fn shingle_text(&self, text: &str) -> SparseBinaryVec {
        let lower = text.to_lowercase();
        let tokens: Vec<&str> = lower.split_ascii_whitespace().collect();
        self.shingle_tokens(&tokens)
    }

    /// Shingle a document given as token ids (the synthetic corpus path —
    /// avoids string formatting in the hot loop).
    pub fn shingle_token_ids(&self, ids: &[u64]) -> SparseBinaryVec {
        if ids.is_empty() {
            return SparseBinaryVec::from_indices(vec![]);
        }
        let hash_window = |win: &[u64]| -> u64 {
            // Mix the ids with a running multiply-xor; cheap and stable.
            let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
            for &id in win {
                h ^= id.wrapping_add(0x2545_F491_4F6C_DD1D);
                h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
                h ^= h >> 33;
            }
            h % self.dim
        };
        let mut idxs = Vec::with_capacity(ids.len().saturating_sub(self.w) + 1);
        if ids.len() < self.w {
            idxs.push(hash_window(ids));
        } else {
            for win in ids.windows(self.w) {
                idxs.push(hash_window(win));
            }
        }
        SparseBinaryVec::from_indices(idxs)
    }

    fn hash_shingle(&self, tokens: &[&str]) -> u64 {
        let mut buf = Vec::with_capacity(tokens.iter().map(|t| t.len() + 1).sum());
        for (i, t) in tokens.iter().enumerate() {
            if i > 0 {
                buf.push(0x1f); // unit separator — unambiguous joining
            }
            buf.extend_from_slice(t.as_bytes());
        }
        fnv1a64(&buf) % self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_values() {
        // FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn shingle_count_matches_window_count() {
        let s = Shingler::new(3, u64::MAX);
        let v = s.shingle_text("the quick brown fox jumps");
        // 5 tokens, w=3 -> 3 windows, all distinct with high probability.
        assert_eq!(v.nnz(), 3);
    }

    #[test]
    fn short_documents_yield_one_shingle() {
        let s = Shingler::new(5, u64::MAX);
        let v = s.shingle_text("hello world");
        assert_eq!(v.nnz(), 1);
        let e = s.shingle_text("");
        assert_eq!(e.nnz(), 0);
    }

    #[test]
    fn identical_texts_shingle_identically() {
        let s = Shingler::new(3, 1 << 24);
        let a = s.shingle_text("a b c d e f");
        let b = s.shingle_text("A  B C d E f"); // case/whitespace-insensitive
        assert_eq!(a, b);
    }

    #[test]
    fn near_duplicates_have_high_resemblance() {
        let s = Shingler::new(3, 1 << 30);
        let base = "lorem ipsum dolor sit amet consectetur adipiscing elit sed do \
                    eiusmod tempor incididunt ut labore et dolore magna aliqua";
        let edited = "lorem ipsum dolor sit amet consectetur adipiscing elit sed do \
                      eiusmod tempor incididunt ut labore et dolore magna MUTATED";
        let a = s.shingle_text(base);
        let b = s.shingle_text(edited);
        let r = a.resemblance(&b);
        assert!(r > 0.7, "resemblance {r}");
        let unrelated = s.shingle_text("completely different text with other words \
                                        entirely nothing shared at all here");
        assert!(a.resemblance(&unrelated) < 0.05);
    }

    #[test]
    fn token_ids_deterministic_and_separating() {
        let s = Shingler::new(3, 1 << 24);
        let a = s.shingle_token_ids(&[1, 2, 3, 4, 5]);
        let b = s.shingle_token_ids(&[1, 2, 3, 4, 5]);
        let c = s.shingle_token_ids(&[5, 4, 3, 2, 1]);
        assert_eq!(a, b);
        assert!(a.resemblance(&c) < 0.5);
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn dim_bounds_indices() {
        let s = Shingler::new(2, 97);
        let v = s.shingle_text("one two three four five six seven eight");
        assert!(v.indices().iter().all(|&i| i < 97));
    }
}
