//! Synthetic webspam-like corpus generator.
//!
//! The paper's experiments use the *webspam* dataset (n = 350 000,
//! D = 16 609 143, 24 GB in LIBSVM format — not redistributable here), so
//! this module builds the closest synthetic equivalent exercising the same
//! code paths (DESIGN.md §6):
//!
//! * a **power-law (Zipf) vocabulary** — the paper's §1.1 justification for
//!   binary shingles rests on word-frequency power laws;
//! * **two document classes** ("spam" vs "ham") built from class-specific
//!   **phrase books** blended with background Zipf tokens. Phrases are
//!   multi-token runs, so same-class documents share *contiguous* token
//!   windows — i.e. shared w-shingles — exactly how template reuse makes
//!   real spam pages resemble each other. Isolated class-token mixtures do
//!   NOT work here: w-shingling destroys unigram signal, and the resulting
//!   corpus has chance-level resemblance structure (we verified this —
//!   see `same_class_documents_are_more_similar`).
//! * **w-shingling** of each token stream into a D-dimensional binary set
//!   (default w = 3, matching webspam's 3-shingles).
//!
//! Document generation is seeded per document, so corpora are identical
//! regardless of sharding/threading in the pipeline (L3 determinism test).

use super::shingle::Shingler;
use super::sparse::{SparseBinaryDataset, SparseBinaryVec};
use crate::rng::Xoshiro256;

/// Corpus generator configuration.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Number of documents.
    pub n_docs: usize,
    /// Shingle space size D.
    pub dim: u64,
    /// Vocabulary size (token universe before shingling).
    pub vocab: usize,
    /// Zipf exponent of the background distribution (~1.1 for natural text).
    pub zipf_s: f64,
    /// Shingle width w.
    pub w: usize,
    /// Mean document length in tokens (lengths ~ shifted geometric).
    pub mean_len: usize,
    /// Fraction of emitted segments drawn from the class phrase book
    /// (0..1). Higher = more separable classes.
    pub topic_mix: f64,
    /// Number of phrases per class phrase book.
    pub topic_size: usize,
    /// Tokens per phrase (>= shingle width w for full shared shingles).
    pub phrase_len: usize,
    /// Fraction of positive-class documents.
    pub pos_fraction: f64,
    /// Master seed.
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            n_docs: 10_000,
            dim: 1 << 24,
            vocab: 50_000,
            zipf_s: 1.1,
            w: 3,
            mean_len: 120,
            topic_mix: 0.35,
            topic_size: 150,
            phrase_len: 5,
            pos_fraction: 0.5,
            seed: 20110001,
        }
    }
}

/// Precomputed sampling tables for one corpus.
pub struct CorpusSampler {
    cfg: SynthConfig,
    /// Cumulative background Zipf distribution over the vocabulary.
    zipf_cdf: Vec<f64>,
    /// Phrase books per class (index 0 = negative, 1 = positive): each
    /// phrase is a fixed token run; reuse across documents of the same
    /// class creates the shared shingles that carry the class signal.
    phrases: [Vec<Vec<u64>>; 2],
    shingler: Shingler,
}

impl CorpusSampler {
    pub fn new(cfg: SynthConfig) -> Self {
        assert!(cfg.vocab >= 100, "vocab too small");
        assert!((0.0..=1.0).contains(&cfg.topic_mix));
        assert!(cfg.phrase_len >= 1);
        // Background Zipf CDF: p(rank r) ∝ 1 / r^s.
        let mut cdf = Vec::with_capacity(cfg.vocab);
        let mut acc = 0.0;
        for r in 1..=cfg.vocab {
            acc += 1.0 / (r as f64).powf(cfg.zipf_s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        // Phrase books: deterministic from the corpus seed; tokens drawn
        // from the mid-frequency band [vocab/10, vocab/2) — out of both the
        // stop-word head (shared by everything) and the ultra-rare tail.
        let band_lo = (cfg.vocab / 10) as u64;
        let band_hi = (cfg.vocab / 2).max(cfg.vocab / 10 + 100) as u64;
        let mut book_rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0xB00C_B00C);
        let mut make_book = || -> Vec<Vec<u64>> {
            (0..cfg.topic_size)
                .map(|_| {
                    (0..cfg.phrase_len)
                        .map(|_| band_lo + book_rng.gen_range(band_hi - band_lo))
                        .collect()
                })
                .collect()
        };
        let p0 = make_book();
        let p1 = make_book();
        let shingler = Shingler::new(cfg.w, cfg.dim);
        Self {
            cfg,
            zipf_cdf: cdf,
            phrases: [p0, p1],
            shingler,
        }
    }

    pub fn config(&self) -> &SynthConfig {
        &self.cfg
    }

    fn sample_background(&self, rng: &mut Xoshiro256) -> u64 {
        let u = rng.gen_f64();
        // Binary search the CDF.
        let mut lo = 0usize;
        let mut hi = self.zipf_cdf.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.zipf_cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u64
    }

    /// Generate document `doc_id` deterministically: token stream + label.
    pub fn generate_tokens(&self, doc_id: u64) -> (Vec<u64>, f32) {
        let mut rng = Xoshiro256::seed_from_u64(
            self.cfg.seed ^ doc_id.wrapping_mul(0xA24B_AED4_963E_E407),
        );
        let positive = rng.gen_f64() < self.cfg.pos_fraction;
        let class = positive as usize;
        // Shifted-geometric length with mean ~ mean_len (min length 2w).
        let p = 1.0 / self.cfg.mean_len as f64;
        let mut len = 0usize;
        while rng.gen_f64() > p {
            len += 1;
            if len >= 8 * self.cfg.mean_len {
                break;
            }
        }
        let len = len.max(2 * self.cfg.w.max(self.cfg.phrase_len));
        let book = &self.phrases[class];
        let mut tokens: Vec<u64> = Vec::with_capacity(len + self.cfg.phrase_len);
        while tokens.len() < len {
            if rng.gen_f64() < self.cfg.topic_mix {
                // Emit a whole class phrase: contiguous tokens ⇒ the
                // phrase-internal w-shingles are shared across documents.
                let p = &book[rng.gen_range(book.len() as u64) as usize];
                tokens.extend_from_slice(p);
            } else {
                tokens.push(self.sample_background(&mut rng));
            }
        }
        (tokens, if positive { 1.0 } else { -1.0 })
    }

    /// Generate the shingled sparse vector for document `doc_id`.
    pub fn generate(&self, doc_id: u64) -> (SparseBinaryVec, f32) {
        let (tokens, label) = self.generate_tokens(doc_id);
        (self.shingler.shingle_token_ids(&tokens), label)
    }
}

/// Generate a full corpus into a [`SparseBinaryDataset`] (single-threaded;
/// the L3 pipeline in `coordinator::pipeline` does the same sharded).
pub fn generate_corpus(cfg: &SynthConfig) -> SparseBinaryDataset {
    let sampler = CorpusSampler::new(cfg.clone());
    let mut ds = SparseBinaryDataset::new(cfg.dim);
    for doc_id in 0..cfg.n_docs as u64 {
        let (v, y) = sampler.generate(doc_id);
        ds.push(v, y);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SynthConfig {
        SynthConfig {
            n_docs: 200,
            dim: 1 << 20,
            vocab: 5_000,
            topic_size: 100,
            mean_len: 60,
            ..Default::default()
        }
    }

    #[test]
    fn corpus_is_deterministic() {
        let cfg = small_cfg();
        let a = generate_corpus(&cfg);
        let b = generate_corpus(&cfg);
        assert_eq!(a.n(), b.n());
        for i in 0..a.n() {
            assert_eq!(a.row(i), b.row(i));
            assert_eq!(a.label(i), b.label(i));
        }
    }

    #[test]
    fn corpus_has_both_classes_roughly_balanced() {
        let ds = generate_corpus(&small_cfg());
        let pos = ds.labels().iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / ds.n() as f64;
        assert!((0.35..0.65).contains(&frac), "pos fraction {frac}");
    }

    #[test]
    fn documents_are_sparse_and_in_range() {
        let cfg = small_cfg();
        let ds = generate_corpus(&cfg);
        assert!(ds.avg_nnz() > 10.0, "avg nnz {}", ds.avg_nnz());
        assert!(ds.avg_nnz() < 4.0 * cfg.mean_len as f64);
        for i in 0..ds.n() {
            assert!(ds.row(i).iter().all(|&x| x < cfg.dim));
        }
    }

    #[test]
    fn same_class_documents_are_more_similar() {
        // The resemblance signal the classifiers must exploit: average
        // within-class resemblance exceeds between-class resemblance.
        let ds = generate_corpus(&small_cfg());
        let mut within = (0.0, 0usize);
        let mut between = (0.0, 0usize);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let r = ds.row_vec(i).resemblance(&ds.row_vec(j));
                if ds.label(i) == ds.label(j) {
                    within.0 += r;
                    within.1 += 1;
                } else {
                    between.0 += r;
                    between.1 += 1;
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(w > b, "within {w} <= between {b}");
    }

    #[test]
    fn generate_tokens_is_per_doc_deterministic() {
        let sampler = CorpusSampler::new(small_cfg());
        let (t1, y1) = sampler.generate_tokens(17);
        let (t2, y2) = sampler.generate_tokens(17);
        assert_eq!(t1, t2);
        assert_eq!(y1, y2);
        // Different docs differ.
        let (t3, _) = sampler.generate_tokens(18);
        assert_ne!(t1, t3);
    }

    #[test]
    fn zipf_head_is_heavier_than_tail() {
        let sampler = CorpusSampler::new(small_cfg());
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if sampler.sample_background(&mut rng) < 50 {
                head += 1;
            }
        }
        // Top-50 of a Zipf(1.1) over 5000 words carries a large share.
        assert!(head as f64 / n as f64 > 0.25, "head mass {}", head as f64 / n as f64);
    }
}
