//! Sparse binary vectors and datasets (CSR layout).
//!
//! The paper's data model: each example is a *set* S ⊆ Ω = {0, …, D−1}
//! (equivalently a 0/1 vector of dimension D with |S| non-zeros). We store
//! sorted `u64` feature indices so D can be as large as 2^64 (paper §1.1).

use std::fmt;

/// A single sparse binary example: sorted, deduplicated feature indices.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparseBinaryVec {
    indices: Vec<u64>,
}

impl SparseBinaryVec {
    /// Build from arbitrary indices (sorts and deduplicates).
    pub fn from_indices(mut indices: Vec<u64>) -> Self {
        indices.sort_unstable();
        indices.dedup();
        Self { indices }
    }

    /// Build from indices already sorted and unique (checked in debug).
    pub fn from_sorted_unique(indices: Vec<u64>) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        Self { indices }
    }

    /// Sorted feature indices.
    #[inline]
    pub fn indices(&self) -> &[u64] {
        &self.indices
    }

    /// Number of non-zeros, f = |S|.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// |S1 ∩ S2| via linear merge (both sides sorted).
    pub fn intersection_size(&self, other: &Self) -> usize {
        let (mut i, mut j, mut a) = (0, 0, 0);
        let (x, y) = (&self.indices, &other.indices);
        while i < x.len() && j < y.len() {
            match x[i].cmp(&y[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    a += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        a
    }

    /// |S1 ∪ S2| = f1 + f2 − a.
    pub fn union_size(&self, other: &Self) -> usize {
        self.nnz() + other.nnz() - self.intersection_size(other)
    }

    /// Resemblance R = |S1 ∩ S2| / |S1 ∪ S2| (paper §2). Empty∪empty → 0.
    pub fn resemblance(&self, other: &Self) -> f64 {
        let u = self.union_size(other);
        if u == 0 {
            0.0
        } else {
            self.intersection_size(other) as f64 / u as f64
        }
    }

    /// Binary inner product a = Σ u1_i·u2_i = |S1 ∩ S2|.
    pub fn dot_binary(&self, other: &Self) -> usize {
        self.intersection_size(other)
    }
}

/// A labeled sparse binary dataset in CSR layout.
///
/// Row i occupies `indices[indptr[i]..indptr[i+1]]`; `labels[i] ∈ {−1,+1}`.
#[derive(Clone, Debug, Default)]
pub struct SparseBinaryDataset {
    indptr: Vec<usize>,
    indices: Vec<u64>,
    labels: Vec<f32>,
    dim: u64,
}

impl SparseBinaryDataset {
    pub fn new(dim: u64) -> Self {
        Self {
            indptr: vec![0],
            indices: Vec::new(),
            labels: Vec::new(),
            dim,
        }
    }

    /// Construct from rows (each row is sorted+deduped on insert).
    pub fn from_rows(rows: Vec<(SparseBinaryVec, f32)>, dim: u64) -> Self {
        let mut ds = Self::new(dim);
        for (v, y) in rows {
            ds.push(v, y);
        }
        ds
    }

    /// Append an example.
    pub fn push(&mut self, v: SparseBinaryVec, label: f32) {
        self.push_sorted_slice(v.indices(), label);
    }

    /// Append an example from already-sorted, unique indices without
    /// building an owned [`SparseBinaryVec`] — the bulk-ingest path
    /// (checked in debug builds).
    pub fn push_sorted_slice(&mut self, indices: &[u64], label: f32) {
        debug_assert!(label == 1.0 || label == -1.0, "labels are ±1");
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        if let Some(&max) = indices.last() {
            assert!(max < self.dim, "index {max} out of dim {}", self.dim);
        }
        self.indices.extend_from_slice(indices);
        self.indptr.push(self.indices.len());
        self.labels.push(label);
    }

    /// Pre-allocate for `rows` more rows totalling `nnz` more non-zeros.
    pub fn reserve(&mut self, rows: usize, nnz: usize) {
        self.indptr.reserve(rows);
        self.indices.reserve(nnz);
        self.labels.reserve(rows);
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn dim(&self) -> u64 {
        self.dim
    }

    /// Total non-zeros across all rows.
    pub fn total_nnz(&self) -> usize {
        self.indices.len()
    }

    /// Average non-zeros per row (the paper's `c`).
    pub fn avg_nnz(&self) -> f64 {
        if self.n() == 0 {
            0.0
        } else {
            self.total_nnz() as f64 / self.n() as f64
        }
    }

    /// Row i's sorted feature indices (zero-copy).
    #[inline]
    pub fn row(&self, i: usize) -> &[u64] {
        &self.indices[self.indptr[i]..self.indptr[i + 1]]
    }

    /// Row i as an owned vector.
    pub fn row_vec(&self, i: usize) -> SparseBinaryVec {
        SparseBinaryVec::from_sorted_unique(self.row(i).to_vec())
    }

    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.labels[i]
    }

    pub fn labels(&self) -> &[f32] {
        &self.labels
    }

    /// Iterate `(row_indices, label)`.
    pub fn iter(&self) -> impl Iterator<Item = (&[u64], f32)> + '_ {
        (0..self.n()).map(move |i| (self.row(i), self.labels[i]))
    }

    /// Split into (train, test) by a deterministic shuffled index set;
    /// `test_fraction` of rows go to test (the paper uses 20%).
    pub fn train_test_split(&self, test_fraction: f64, seed: u64) -> (Self, Self) {
        assert!((0.0..1.0).contains(&test_fraction));
        let mut order: Vec<usize> = (0..self.n()).collect();
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(seed);
        rng.shuffle(&mut order);
        let n_test = (self.n() as f64 * test_fraction).round() as usize;
        let mut train = Self::new(self.dim);
        let mut test = Self::new(self.dim);
        for (pos, &i) in order.iter().enumerate() {
            let target = if pos < n_test { &mut test } else { &mut train };
            target.push(self.row_vec(i), self.labels[i]);
        }
        (train, test)
    }

    /// Subset by row indices.
    pub fn subset(&self, rows: &[usize]) -> Self {
        let mut out = Self::new(self.dim);
        for &i in rows {
            out.push(self.row_vec(i), self.labels[i]);
        }
        out
    }

    /// In-memory size of the raw representation in bytes (indices + ptrs).
    pub fn storage_bytes(&self) -> usize {
        self.indices.len() * 8 + self.indptr.len() * 8 + self.labels.len() * 4
    }
}

impl fmt::Display for SparseBinaryDataset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "SparseBinaryDataset(n={}, dim={}, nnz={}, avg_nnz={:.1})",
            self.n(),
            self.dim(),
            self.total_nnz(),
            self.avg_nnz()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(idx: &[u64]) -> SparseBinaryVec {
        SparseBinaryVec::from_indices(idx.to_vec())
    }

    #[test]
    fn from_indices_sorts_and_dedups() {
        let x = v(&[5, 1, 3, 1, 5]);
        assert_eq!(x.indices(), &[1, 3, 5]);
        assert_eq!(x.nnz(), 3);
    }

    #[test]
    fn intersection_union_resemblance() {
        let a = v(&[1, 2, 3, 4]);
        let b = v(&[3, 4, 5]);
        assert_eq!(a.intersection_size(&b), 2);
        assert_eq!(a.union_size(&b), 5);
        assert!((a.resemblance(&b) - 0.4).abs() < 1e-12);
        assert_eq!(a.dot_binary(&b), 2);
    }

    #[test]
    fn resemblance_identical_and_disjoint() {
        let a = v(&[10, 20, 30]);
        assert_eq!(a.resemblance(&a), 1.0);
        let b = v(&[40, 50]);
        assert_eq!(a.resemblance(&b), 0.0);
        let e = v(&[]);
        assert_eq!(e.resemblance(&e), 0.0);
    }

    #[test]
    fn dataset_rows_roundtrip() {
        let mut ds = SparseBinaryDataset::new(100);
        ds.push(v(&[1, 5, 9]), 1.0);
        ds.push(v(&[2]), -1.0);
        ds.push(v(&[]), 1.0);
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.row(0), &[1, 5, 9]);
        assert_eq!(ds.row(1), &[2]);
        assert_eq!(ds.row(2), &[] as &[u64]);
        assert_eq!(ds.label(1), -1.0);
        assert_eq!(ds.total_nnz(), 4);
        assert!((ds.avg_nnz() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn push_rejects_out_of_range() {
        let mut ds = SparseBinaryDataset::new(10);
        ds.push(v(&[10]), 1.0);
    }

    #[test]
    fn split_partitions_all_rows() {
        let mut ds = SparseBinaryDataset::new(1000);
        for i in 0..100u64 {
            ds.push(v(&[i, i + 100]), if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let (tr, te) = ds.train_test_split(0.2, 42);
        assert_eq!(tr.n(), 80);
        assert_eq!(te.n(), 20);
        assert_eq!(tr.total_nnz() + te.total_nnz(), ds.total_nnz());
        // Determinism.
        let (tr2, te2) = ds.train_test_split(0.2, 42);
        assert_eq!(tr.row(0), tr2.row(0));
        assert_eq!(te.row(0), te2.row(0));
    }

    #[test]
    fn subset_selects_rows() {
        let mut ds = SparseBinaryDataset::new(50);
        ds.push(v(&[1]), 1.0);
        ds.push(v(&[2]), -1.0);
        ds.push(v(&[3]), 1.0);
        let s = ds.subset(&[2, 0]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.row(0), &[3]);
        assert_eq!(s.row(1), &[1]);
    }
}
