//! Sparse *real-valued* dataset (CSR) — the output format of VW feature
//! hashing and random projections (paper §6–§8). The binary substrate in
//! [`super::sparse`] covers the paper's main path; this covers the
//! baselines, whose hashed samples are signed sums.

/// Labeled sparse real-valued dataset; row entries are (index, value).
#[derive(Clone, Debug, Default)]
pub struct SparseRealDataset {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    labels: Vec<f32>,
    dim: usize,
}

impl SparseRealDataset {
    pub fn new(dim: usize) -> Self {
        Self {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            dim,
        }
    }

    /// Append a row of (index, value) pairs (must be index-sorted).
    pub fn push(&mut self, row: &[(u32, f32)], label: f32) {
        debug_assert!(row.windows(2).all(|w| w[0].0 < w[1].0));
        for &(i, v) in row {
            assert!((i as usize) < self.dim, "index {i} out of dim {}", self.dim);
            self.indices.push(i);
            self.values.push(v);
        }
        self.indptr.push(self.indices.len());
        self.labels.push(label);
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.labels[i]
    }

    /// Row i as parallel (indices, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// ‖x_i‖².
    pub fn row_norm_sq(&self, i: usize) -> f64 {
        self.row(i).1.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// w·x_i.
    pub fn dot(&self, i: usize, w: &[f32]) -> f64 {
        let (idx, val) = self.row(i);
        idx.iter()
            .zip(val)
            .map(|(&j, &v)| w[j as usize] as f64 * v as f64)
            .sum()
    }

    /// w += scale·x_i.
    pub fn axpy(&self, i: usize, scale: f64, w: &mut [f32]) {
        let (idx, val) = self.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            w[j as usize] += (scale * v as f64) as f32;
        }
    }

    /// Total stored non-zeros.
    pub fn total_nnz(&self) -> usize {
        self.indices.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_row_roundtrip() {
        let mut ds = SparseRealDataset::new(10);
        ds.push(&[(1, 0.5), (4, -2.0)], 1.0);
        ds.push(&[], -1.0);
        assert_eq!(ds.n(), 2);
        let (idx, val) = ds.row(0);
        assert_eq!(idx, &[1, 4]);
        assert_eq!(val, &[0.5, -2.0]);
        assert_eq!(ds.row(1).0.len(), 0);
        assert!((ds.row_norm_sq(0) - 4.25).abs() < 1e-9);
    }

    #[test]
    fn dot_axpy_consistent() {
        let mut ds = SparseRealDataset::new(6);
        ds.push(&[(0, 1.0), (2, 3.0)], 1.0);
        let mut w = vec![0.0f32; 6];
        ds.axpy(0, 0.5, &mut w);
        assert_eq!(w[0], 0.5);
        assert_eq!(w[2], 1.5);
        assert!((ds.dot(0, &w) - (0.5 + 4.5)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn rejects_out_of_range() {
        let mut ds = SparseRealDataset::new(3);
        ds.push(&[(3, 1.0)], 1.0);
    }
}
