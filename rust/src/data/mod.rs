//! Datasets: sparse binary storage, LIBSVM I/O, shingling and the synthetic
//! webspam-like corpus generator.
//!
//! The paper's workload is massive, sparse, *binary*, ultra-high-dimensional
//! data (w-shingled documents over a dictionary of up to 2^64 — paper §1.1).
//! [`sparse`] holds the CSR-style in-memory representation used everywhere
//! downstream; [`libsvm`] reads/writes the interchange format the paper's
//! experiments used (webspam was distributed in LIBSVM format); [`shingle`]
//! turns raw text into w-shingle feature sets; [`synth`] generates the
//! webspam-scale-down substitute corpus (see DESIGN.md §6).

pub mod libsvm;
pub mod real;
pub mod shingle;
pub mod sparse;
pub mod synth;

pub use real::SparseRealDataset;
pub use sparse::{SparseBinaryDataset, SparseBinaryVec};
pub use synth::{SynthConfig, generate_corpus};
