//! A miniature property-testing helper (proptest is unavailable offline).
//!
//! [`check`] runs a property closure against `cases` independently seeded
//! RNGs and, on failure, reports the failing seed so the case can be
//! replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libxla_extension rpath in this
//! // offline image; the identical pattern is exercised by unit tests.)
//! use bbml::proptest_mini::check;
//! check("addition commutes", 100, |rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! There is no shrinking — properties here are numeric invariants where the
//! failing seed plus the assertion message is diagnostic enough.

use crate::rng::Xoshiro256;

/// Run `prop` for `cases` random cases. Panics (with the seed) on failure.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Xoshiro256)) {
    // A fixed base seed keeps CI deterministic; override with BBML_PROP_SEED.
    let base: u64 = std::env::var("BBML_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // bbml-lint: allow(no-unwrap) reason: this panic IS the
            // property harness's failure reporter — it must abort the test
            // with the replay seed, exactly like assert! would.
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 BBML_PROP_SEED={base} — failing seed {seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Sampling helpers shared by property tests.
pub mod gen {
    use crate::rng::Xoshiro256;

    /// Random sparse binary set: `len` distinct indices in `[0, d)`.
    pub fn sparse_set(rng: &mut Xoshiro256, d: u64, min_len: usize, max_len: usize) -> Vec<u64> {
        let len = min_len + rng.gen_range((max_len - min_len + 1) as u64) as usize;
        let mut v = rng.sample_distinct(d, len.min(d as usize).max(1));
        v.sort_unstable();
        v
    }

    /// Two sets with a controlled overlap, returning (s1, s2).
    pub fn overlapping_sets(
        rng: &mut Xoshiro256,
        d: u64,
        f1: usize,
        f2: usize,
        a: usize,
    ) -> (Vec<u64>, Vec<u64>) {
        assert!(a <= f1.min(f2) && f1 + f2 - a <= d as usize);
        let union = rng.sample_distinct(d, f1 + f2 - a);
        let s1: Vec<u64> = union[..f1].to_vec();
        let mut s2: Vec<u64> = union[f1 - a..f1 + f2 - a].to_vec();
        let mut s1s = s1;
        s1s.sort_unstable();
        s2.sort_unstable();
        (s1s, s2)
    }

    /// Dense real vector with entries ~ N(0, 1).
    pub fn dense_vec(rng: &mut Xoshiro256, d: usize) -> Vec<f64> {
        (0..d).map(|_| rng.gen_normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.gen_range(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_reports_seed() {
        check("falsum", 10, |rng| {
            let x = rng.gen_range(10);
            assert!(x < 5, "x = {x}");
        });
    }

    #[test]
    fn overlapping_sets_have_requested_cardinalities() {
        check("overlap cardinalities", 100, |rng| {
            let (f1, f2, a) = (20, 15, 7);
            let (s1, s2) = gen::overlapping_sets(rng, 10_000, f1, f2, a);
            assert_eq!(s1.len(), f1);
            assert_eq!(s2.len(), f2);
            let set1: std::collections::HashSet<_> = s1.iter().collect();
            let inter = s2.iter().filter(|x| set1.contains(x)).count();
            assert_eq!(inter, a);
        });
    }
}
