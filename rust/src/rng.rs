//! Deterministic, dependency-free PRNGs.
//!
//! The `rand` crate is unavailable in this offline environment, so the
//! library carries its own small generators: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse. Both are well-studied,
//! tiny, and — crucially for the experiment harness — *stable across runs
//! and platforms*, so every figure is regenerated from an explicit seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Snapshot of the raw generator state — the checkpoint serialization
    /// surface: a generator rebuilt via [`Self::from_state`] continues the
    /// exact output stream, which is what makes seeded shuffles resumable.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Self::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rare fallback: correct for modulo bias.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (pairs are not cached — simplicity
    /// over the last ~30% of throughput; the hot paths use uniforms).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Rademacher ±1 with equal probability (VW's pre-multiplier, s = 1).
    #[inline]
    pub fn gen_sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, m: usize) -> Vec<u64> {
        assert!(m as u64 <= n, "cannot sample {m} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(m);
        let mut out = Vec::with_capacity(m);
        for j in (n - m as u64)..n {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_is_deterministic_and_differs_by_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        let mut c = Xoshiro256::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_is_in_bounds_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let n = 10u64;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = r.gen_range(n);
            assert!(x < n);
            counts[x as usize] += 1;
        }
        for &c in &counts {
            // Each bucket ~10000; allow generous CLT slack.
            assert!((c as i64 - 10_000).abs() < 600, "count {c}");
        }
    }

    #[test]
    fn gen_f64_in_unit_interval_with_correct_mean() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn state_snapshot_resumes_the_stream() {
        let mut a = Xoshiro256::seed_from_u64(101);
        for _ in 0..5 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let mut b = Xoshiro256::from_state(snap);
        let resumed: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed, "from_state must continue the exact stream");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_produces_distinct_in_range() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&x| x < 100));
    }

    #[test]
    fn sign_is_balanced() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let sum: f64 = (0..100_000).map(|_| r.gen_sign()).sum();
        assert!(sum.abs() < 1500.0, "sum {sum}");
    }
}
