//! Encode-path latency: raw document set → packed b-bit signature words.
//!
//! Three questions, matching the fused-encode work:
//!
//! 1. **Lane width** — per-row fold-min cost of the per-permutation scalar
//!    scan vs the 4-wide and 8-wide one-pass engines (`fold_min_into_x4`
//!    vs `fold_min_into`), across k.
//! 2. **Fused packing** — full encode via the legacy route (64-bit lanes →
//!    `pack_lowest_bits` u16 detour → `push_row`) vs the fused route
//!    (`signature_packed_into` + `push_row_from_lanes`), across b.
//! 3. **Rows/s** — end-to-end encode throughput over a synthetic batch,
//!    the number the ROADMAP perf note quotes.
//!
//! Results land in `results/BENCH_encode.{json,csv}` (median/p95 latency
//! plus median-based items/s for the throughput entries). Set
//! `BBML_BENCH_FAST=1` for a CI-sized run.

use bbml::benchkit::{black_box, Bencher};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::bbit::BbitSignatureMatrix;
use bbml::hashing::minwise::MinwiseHasher;
use bbml::hashing::pack_lowest_bits;
use bbml::hashing::perm::PermutationBank;

fn main() {
    let mut b = Bencher::new();
    let cfg = SynthConfig {
        n_docs: 64,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let docs: Vec<Vec<u64>> = (0..ds.n()).map(|i| ds.row(i).to_vec()).collect();
    let n_rows = docs.len() as u64;
    println!(
        "workload: {} docs, avg nnz {:.1}, dim 2^24",
        docs.len(),
        ds.avg_nnz()
    );

    // --- 1. lane width: scalar vs 4-wide vs 8-wide fold-min, across k ----
    for k in [30usize, 64, 200, 500] {
        let h = MinwiseHasher::new(cfg.dim, k, 1);
        let bank = PermutationBank::new(cfg.dim, 1, k);
        let mut lanes = Vec::new();

        b.bench_throughput(&format!("fold/scalar k={k}"), n_rows, || {
            for doc in &docs {
                h.signature_scalar_into(black_box(doc), &mut lanes);
            }
            lanes.len()
        });
        b.bench_throughput(&format!("fold/x4 k={k}"), n_rows, || {
            for doc in &docs {
                lanes.clear();
                lanes.resize(k, u64::MAX);
                bank.fold_min_into_x4(black_box(doc), &mut lanes);
            }
            lanes.len()
        });
        // The production engine: 8-wide groups (SIMD when the
        // `portable-simd` feature is on), 4-wide + scalar tails.
        b.bench_throughput(&format!("fold/x8 k={k}"), n_rows, || {
            for doc in &docs {
                h.signature_batch_into(black_box(doc), &mut lanes);
            }
            lanes.len()
        });
    }

    // --- 2. packing: legacy u16 detour vs fused lanes→words, across b ----
    let k = 200usize;
    let h = MinwiseHasher::new(cfg.dim, k, 1);
    for bits in [1u32, 4, 8, 16] {
        let mut lanes = Vec::new();
        let mut words = Vec::new();

        b.bench_throughput(&format!("encode/legacy k={k} b={bits}"), n_rows, || {
            let mut m = BbitSignatureMatrix::new(k, bits);
            for doc in &docs {
                h.signature_batch_into(black_box(doc), &mut lanes);
                m.push_row(&pack_lowest_bits(&lanes, bits), 0.0);
            }
            m.n()
        });
        b.bench_throughput(&format!("encode/fused k={k} b={bits}"), n_rows, || {
            let mut m = BbitSignatureMatrix::new(k, bits);
            for doc in &docs {
                h.signature_packed_into(black_box(doc), bits, &mut lanes, &mut words);
                m.push_packed_row(&words, 0.0);
            }
            m.n()
        });
    }

    // --- 3. headline rows/s: the full fused encode at the paper's scale --
    for (k, bits) in [(200usize, 4u32), (500, 1)] {
        let h = MinwiseHasher::new(cfg.dim, k, 1);
        let mut lanes = Vec::new();
        let mut words = Vec::new();
        b.bench_throughput(&format!("rows_per_sec/fused k={k} b={bits}"), n_rows, || {
            let mut acc = 0u64;
            for doc in &docs {
                h.signature_packed_into(black_box(doc), bits, &mut lanes, &mut words);
                acc ^= words[0];
            }
            acc
        });
    }

    b.write_json("results/BENCH_encode.json").unwrap();
    b.write_csv("results/BENCH_encode.csv").unwrap();
}
