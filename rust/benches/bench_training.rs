//! Figure 3 / Figure 7: training time — hashed (per b, k) vs original data,
//! for both DCD linear SVM and DCD logistic regression.

use bbml::benchkit::Bencher;
use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::solvers::linear_svm::{train_svm, SvmLoss, SvmOptions};
use bbml::solvers::logreg::{train_logreg, LogRegOptions};
use bbml::solvers::ExpandedView;

fn main() {
    let mut b = Bencher::new();
    let cfg = SynthConfig {
        n_docs: 3_000,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        topic_mix: 0.25,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let (train, _) = ds.train_test_split(0.2, 1);
    println!("workload: n_train = {}, avg nnz {:.0}", train.n(), train.avg_nnz());
    let pipe = PipelineOptions::default();

    // --- original-data training (the dashed red curve) --------------------
    b.bench_once("train/svm/original", || {
        train_svm(
            &train,
            &SvmOptions {
                c: 1.0,
                loss: SvmLoss::L2,
                ..Default::default()
            },
        )
    });
    b.bench_once("train/logreg/original", || {
        train_logreg(
            &train,
            &LogRegOptions {
                c: 1.0,
                ..Default::default()
            },
        )
    });

    // --- hashed training across (b, k) ------------------------------------
    for &(bbits, k) in &[(1u32, 200usize), (4, 200), (8, 30), (8, 200), (8, 500), (16, 200)] {
        let (sigs, _) = hash_dataset(&train, k, bbits, 11, &pipe);
        let view = ExpandedView::new(&sigs);
        b.bench_once(&format!("train/svm/hashed b={bbits} k={k}"), || {
            train_svm(
                &view,
                &SvmOptions {
                    c: 1.0,
                    loss: SvmLoss::L2,
                    ..Default::default()
                },
            )
        });
        b.bench_once(&format!("train/logreg/hashed b={bbits} k={k}"), || {
            train_logreg(
                &view,
                &LogRegOptions {
                    c: 1.0,
                    ..Default::default()
                },
            )
        });
    }

    b.write_csv("results/bench_training.csv").ok();
}
