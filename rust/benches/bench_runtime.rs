//! PJRT hot-path cost: compiled predict / train-step / match-count execute
//! latency vs the equivalent pure-rust implementations — quantifies the
//! L3↔runtime boundary overhead (per-batch, amortized).

use bbml::benchkit::{black_box, Bencher};
use bbml::hashing::bbit::BbitSignatureMatrix;
use bbml::rng::Xoshiro256;
use bbml::runtime::{ArtifactKind, Runtime};
use bbml::solvers::{BinaryFeatures, ExpandedView};

fn random_sigs(n: usize, k: usize, b: u32, seed: u64) -> BbitSignatureMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut m = BbitSignatureMatrix::new(k, b);
    for i in 0..n {
        let row: Vec<u16> = (0..k)
            .map(|_| (rng.next_u32() & ((1u32 << b) - 1)) as u16)
            .collect();
        m.push_row(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
    }
    m
}

fn main() {
    let Some(rt) = Runtime::try_default() else {
        println!("no artifacts/ — run `make artifacts` to enable runtime benches");
        return;
    };
    let mut bench = Bencher::new();
    println!("platform: {}", rt.platform());

    let sigs = random_sigs(256, 200, 8, 1);
    let mut rng = Xoshiro256::seed_from_u64(2);
    let w: Vec<f32> = (0..200 * 256).map(|_| rng.gen_f32() - 0.5).collect();

    // Warm the executable cache (compilation excluded from steady-state).
    rt.predict_scores(&sigs, &w).unwrap();

    bench.bench("runtime/predict 256x200 (pjrt)", || {
        black_box(rt.predict_scores(&sigs, &w).unwrap().len())
    });
    let view = ExpandedView::new(&sigs);
    bench.bench("runtime/predict 256x200 (rust)", || {
        let mut acc = 0.0;
        for i in 0..sigs.n() {
            acc += view.dot(i, &w);
        }
        black_box(acc)
    });

    let rows: Vec<usize> = (0..256).collect();
    rt.train_step(ArtifactKind::LogregStep, &sigs, &rows, &w, 1.0, 1e-4)
        .unwrap();
    bench.bench("runtime/logreg_step 256x200 (pjrt)", || {
        rt.train_step(ArtifactKind::LogregStep, &sigs, &rows, &w, 1.0, 1e-4)
            .unwrap()
            .loss
    });
    bench.bench("runtime/svm_step 256x200 (pjrt)", || {
        rt.train_step(ArtifactKind::SvmStep, &sigs, &rows, &w, 1.0, 1e-4)
            .unwrap()
            .loss
    });

    let a = random_sigs(128, 200, 8, 3);
    let b2 = random_sigs(128, 200, 8, 4);
    let ar: Vec<usize> = (0..128).collect();
    rt.match_count(&a, &ar, &b2, &ar).unwrap();
    bench.bench("runtime/match_count 128x128 (pjrt)", || {
        black_box(rt.match_count(&a, &ar, &b2, &ar).unwrap().len())
    });
    bench.bench("runtime/match_count 128x128 (rust)", || {
        let mut acc = 0usize;
        for i in 0..128 {
            for j in 0..128 {
                acc += a.match_count(i, j.min(b2.n() - 1));
            }
        }
        black_box(acc)
    });

    bench.write_csv("results/bench_runtime.csv").ok();
}
