//! Serving-path benchmarks: an in-process scoring server on a loopback
//! socket, hammered by real `ScoreClient` connections.
//!
//! Two questions, matching the serving acceptance numbers:
//!
//! 1. **Rows/s** — end-to-end wire throughput at 1/4/8 concurrent client
//!    threads, batched (64 rows/request) vs single-row requests. The gap
//!    between the two is the framing+syscall overhead a batch amortizes.
//! 2. **Request latency** — single-connection p50/p95/p99 per request
//!    (benchkit records the full percentile set per entry).
//!
//! Results land in `results/BENCH_serving.{json,csv}` — every entry
//! carries `median_ns`/`p95_ns`/`p99_ns` and the throughput entries add
//! median-based `items_per_sec` (rows/s). Set `BBML_BENCH_FAST=1` for a
//! CI-sized run.

use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};

use bbml::benchkit::{black_box, Bencher};
use bbml::coordinator::report::weights_crc32;
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{FeatureMapSpec, Scheme};
use bbml::rng::Xoshiro256;
use bbml::serve::{serve, ModelSlot, ScoreClient, ServeOptions, ServeStats, ServedModel};
use bbml::solvers::LinearModel;
use bbml::store::ModelArtifact;

fn main() {
    let mut b = Bencher::new();
    let fast = std::env::var("BBML_BENCH_FAST").ok().as_deref() == Some("1");
    let reqs_per_thread = if fast { 4 } else { 16 };

    // The served model: b-bit minwise, the paper's sweet spot (k=64, b=4),
    // synthetic weights — serving cost is encode + dot product, which does
    // not care how the weights were trained.
    let dim = 1u64 << 24;
    let spec = FeatureMapSpec::new(Scheme::Bbit, dim, 64, 4, 42);
    let n_weights = spec.layout().train_dim();
    let mut rng = Xoshiro256::seed_from_u64(42);
    let w: Vec<f32> = (0..n_weights).map(|_| rng.gen_f32() - 0.5).collect();
    let artifact = ModelArtifact::new(
        spec,
        LinearModel {
            w,
            iters: 1,
            objective: 0.0,
        },
    )
    .unwrap();
    let crc32 = weights_crc32(&artifact.model.w);
    let served = ServedModel {
        artifact,
        crc32,
        source: "/dev/null".into(),
        mtime: None,
    };

    let slot = Arc::new(ModelSlot::new(served));
    let stats = Arc::new(ServeStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let (slot, stats, stop) = (Arc::clone(&slot), Arc::clone(&stats), Arc::clone(&stop));
        std::thread::spawn(move || {
            let opt = ServeOptions {
                workers: 8,
                ..Default::default()
            };
            serve(listener, slot, stats, &opt, stop).unwrap();
        })
    };

    // The request workload: synthetic shingled documents, the rows a real
    // client would ship raw over the wire.
    let cfg = SynthConfig {
        n_docs: 512,
        dim,
        vocab: 20_000,
        mean_len: 60,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let rows: Vec<Vec<u64>> = (0..ds.n()).map(|i| ds.row(i).to_vec()).collect();
    println!(
        "workload: {} rows, avg nnz {:.1}, server {addr} (k=64, b=4, crc32 {crc32})",
        rows.len(),
        ds.avg_nnz()
    );

    // --- 1. rows/s: client fan-in × batched vs single-row requests -------
    for &threads in &[1usize, 4, 8] {
        for &(label, batch) in &[("batched", 64usize), ("single", 1usize)] {
            // One pre-connected client per thread, reused across
            // iterations so connect cost never pollutes the samples.
            let clients: Vec<Mutex<ScoreClient>> = (0..threads)
                .map(|_| Mutex::new(ScoreClient::connect(addr).unwrap()))
                .collect();
            let rows_ref = &rows;
            let total_rows = (threads * reqs_per_thread * batch) as u64;
            b.bench_throughput(
                &format!("serve/{label} batch={batch} clients={threads}"),
                total_rows,
                || {
                    std::thread::scope(|s| {
                        for client in &clients {
                            s.spawn(move || {
                                let mut c = client.lock().unwrap();
                                for r in 0..reqs_per_thread {
                                    let start = (r * batch) % rows_ref.len();
                                    let end = (start + batch).min(rows_ref.len());
                                    let (crc, scores) = c.score(&rows_ref[start..end]).unwrap();
                                    black_box((crc, scores.len()));
                                }
                            });
                        }
                    });
                },
            );
        }
    }

    // --- 2. per-request latency on one quiet connection ------------------
    let mut client = ScoreClient::connect(addr).unwrap();
    for &batch in &[1usize, 64] {
        b.bench(&format!("latency/batch={batch} clients=1"), || {
            let (crc, scores) = client.score(&rows[..batch]).unwrap();
            black_box((crc, scores.len()));
        });
    }

    println!("server gauges: {}", client.stats().unwrap());
    client.shutdown().unwrap();
    server.join().unwrap();

    b.write_json("results/BENCH_serving.json").unwrap();
    b.write_csv("results/BENCH_serving.csv").unwrap();
}
