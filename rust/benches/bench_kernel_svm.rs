//! §5.1: kernel SVM training cost — exact resemblance kernel on raw sets
//! vs the b-bit estimated kernel across k (the paper's ">1 week vs minutes"
//! contrast, scaled to this testbed).

use bbml::benchkit::Bencher;
use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::solvers::kernel_svm::{
    train_kernel_svm, BbitKernel, KernelSvmOptions, ResemblanceKernel,
};

fn main() {
    let mut bench = Bencher::new();
    let cfg = SynthConfig {
        n_docs: 800,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        topic_mix: 0.25,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    println!("workload: n = {}, avg nnz {:.0}", ds.n(), ds.avg_nnz());
    let opt = KernelSvmOptions {
        max_updates: 20_000,
        ..Default::default()
    };

    bench.bench_once("kernel_svm/exact resemblance", || {
        train_kernel_svm(&ResemblanceKernel { data: &ds }, &opt)
    });

    let pipe = PipelineOptions::default();
    for k in [30usize, 100, 200, 500] {
        let (sigs, _) = hash_dataset(&ds, k, 8, 7, &pipe);
        bench.bench_once(&format!("kernel_svm/bbit k={k} b=8"), || {
            train_kernel_svm(&BbitKernel { sigs: &sigs }, &opt)
        });
    }

    bench.write_csv("results/bench_kernel_svm.csv").ok();
}
