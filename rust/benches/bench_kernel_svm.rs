//! §5.1: kernel SVM training cost — exact resemblance kernel on raw sets
//! vs the b-bit estimated kernel across k (the paper's ">1 week vs minutes"
//! contrast, scaled to this testbed) — plus the `match_count` kernel
//! micro-benchmark that gates it all.
//!
//! The `match_count` micro-benchmark measures, at k = 256 across every b,
//! one Gram row (512 row pairs) through three paths:
//!   * `swar`   — the word-aligned SWAR kernel (`match_count`)
//!   * `scalar` — the seed's generic path (`match_count_scalar`,
//!     one `get_bits` pair per position): the "before" reference
//!   * `block`  — the blocked tile primitive (`match_count_block`)
//! and records everything to `results/BENCH_kernel.json` via benchkit, so
//! the ≥5× SWAR-vs-seed acceptance gate for b ∈ {1, 2, 4} is checked from
//! the recorded medians.
//!
//! The signature micro-benchmark (PR 2) measures the minwise engine that
//! feeds all of the above: the batched one-pass k-lane path
//! (`signature_batch_into`) against the seed's per-permutation scan
//! (`signature_scalar_into`) at fixed element·permutation work, printing
//! the throughput in M elem·perm/s and recording the raw timings to
//! `results/BENCH_signature.json`.

use bbml::benchkit::{black_box, Bencher};
use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::bbit::BbitSignatureMatrix;
use bbml::hashing::minwise::MinwiseHasher;
use bbml::rng::Xoshiro256;
use bbml::solvers::kernel_svm::{
    train_kernel_svm, BbitKernel, KernelSvmOptions, ResemblanceKernel,
};

fn main() {
    // --- signature engine micro-benchmark (one-pass k-lane vs seed) -----
    // Separate Bencher: results/BENCH_signature.json must hold exactly
    // these records, like BENCH_kernel.json holds the match_count ones.
    let mut sig_bench = Bencher::new();
    let dim = 1u64 << 24;
    let mut rng = Xoshiro256::seed_from_u64(4242);
    let doc: Vec<u64> = (0..256).map(|_| rng.gen_range(dim)).collect();
    let mut sig_buf = Vec::new();
    for k in [30usize, 64, 256] {
        let h = MinwiseHasher::new(dim, k, 7);
        let work = (doc.len() * k) as f64;
        let st = sig_bench.bench(
            &format!("signature/batched k={k} nnz={}", doc.len()),
            || {
                h.signature_batch_into(black_box(&doc), &mut sig_buf);
                sig_buf.len()
            },
        );
        let batched_meps = work / st.median.as_secs_f64() / 1e6;
        let st = sig_bench.bench(
            &format!("signature/scalar(seed) k={k} nnz={}", doc.len()),
            || {
                h.signature_scalar_into(black_box(&doc), &mut sig_buf);
                sig_buf.len()
            },
        );
        let scalar_meps = work / st.median.as_secs_f64() / 1e6;
        println!(
            "    signature throughput k={k}: batched {batched_meps:.1} \
             M elem·perm/s vs scalar(seed) {scalar_meps:.1} M elem·perm/s \
             ({:.2}x)",
            batched_meps / scalar_meps
        );
    }
    sig_bench
        .write_json("results/BENCH_signature.json")
        .expect("write results/BENCH_signature.json");

    let mut bench = Bencher::new();

    // --- match_count micro-benchmark (the tentpole's acceptance gate) ---
    let k_sig = 256usize;
    let n_rows = 512usize;
    let gram_rows: Vec<usize> = (0..n_rows).collect();
    for b in [1u32, 2, 4, 8, 16] {
        let mask = (1u32 << b) - 1;
        let mut rng = Xoshiro256::seed_from_u64(90 + b as u64);
        let mut m = BbitSignatureMatrix::with_capacity(k_sig, b, n_rows);
        for i in 0..n_rows {
            let row: Vec<u16> = (0..k_sig).map(|_| (rng.next_u32() & mask) as u16).collect();
            m.push_row(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        bench.bench(&format!("match_count/swar k={k_sig} b={b}"), || {
            let mut acc = 0usize;
            for j in 0..n_rows {
                acc += m.match_count(0, j);
            }
            black_box(acc)
        });
        bench.bench(&format!("match_count/scalar(seed) k={k_sig} b={b}"), || {
            let mut acc = 0usize;
            for j in 0..n_rows {
                acc += m.match_count_scalar(0, j);
            }
            black_box(acc)
        });
        bench.bench(&format!("match_count/block {n_rows}x{n_rows} b={b}"), || {
            black_box(m.match_count_block(&gram_rows, &gram_rows).len())
        });
        bench.bench(
            &format!("match_count/block_par(8) {n_rows}x{n_rows} b={b}"),
            || black_box(m.match_count_block_par(&gram_rows, &gram_rows, 8).len()),
        );
    }
    // The acceptance-gate artifact holds exactly the micro-benchmark
    // records; the e2e results below go to the CSV only. A silent write
    // failure would leave the ≥5× gate with nothing to read, so fail loud.
    bench
        .write_json("results/BENCH_kernel.json")
        .expect("write results/BENCH_kernel.json");

    // --- end-to-end §5.1 contrast ---
    let cfg = SynthConfig {
        n_docs: 800,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        topic_mix: 0.25,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    println!("workload: n = {}, avg nnz {:.0}", ds.n(), ds.avg_nnz());
    let opt = KernelSvmOptions {
        max_updates: 20_000,
        ..Default::default()
    };

    bench.bench_once("kernel_svm/exact resemblance", || {
        train_kernel_svm(&ResemblanceKernel { data: &ds }, &opt)
    });

    let pipe = PipelineOptions::default();
    for k in [30usize, 100, 200, 500] {
        let (sigs, _) = hash_dataset(&ds, k, 8, 7, &pipe);
        bench.bench_once(&format!("kernel_svm/bbit k={k} b=8"), || {
            train_kernel_svm(&BbitKernel { sigs: &sigs }, &opt)
        });
    }

    bench.write_csv("results/bench_kernel_svm.csv").ok();
}
