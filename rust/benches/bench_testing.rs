//! Figure 4: testing (scoring) time — hashed expansion scoring vs original
//! sparse scoring, plus the PJRT-compiled predict path when artifacts exist.

use bbml::benchkit::{black_box, Bencher};
use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::runtime::Runtime;
use bbml::solvers::linear_svm::{train_svm, SvmLoss, SvmOptions};
use bbml::solvers::{BinaryFeatures, ExpandedView};

fn main() {
    let mut bench = Bencher::new();
    let cfg = SynthConfig {
        n_docs: 3_000,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.25, 1);
    let pipe = PipelineOptions::default();

    // Original-data model + scoring.
    let model_orig = train_svm(
        &train,
        &SvmOptions {
            c: 1.0,
            loss: SvmLoss::L2,
            ..Default::default()
        },
    );
    bench.bench(&format!("test/original/n={}", test.n()), || {
        black_box(model_orig.accuracy(&test))
    });

    // Hashed models + scoring across (b, k).
    for &(b, k) in &[(8u32, 200usize), (8, 500), (16, 200), (1, 200)] {
        let (sig_tr, _) = hash_dataset(&train, k, b, 3, &pipe);
        let (sig_te, _) = hash_dataset(&test, k, b, 3, &pipe);
        let view_tr = ExpandedView::new(&sig_tr);
        let model = train_svm(
            &view_tr,
            &SvmOptions {
                c: 1.0,
                loss: SvmLoss::L2,
                ..Default::default()
            },
        );
        let view_te = ExpandedView::new(&sig_te);
        bench.bench(&format!("test/hashed b={b} k={k}/n={}", sig_te.n()), || {
            black_box(model.accuracy(&view_te))
        });
        // PJRT predict path (k=200, b=8 artifact only).
        if b == 8 && k == 200 {
            if let Some(rt) = Runtime::try_default() {
                bench.bench("test/pjrt predict b=8 k=200", || {
                    rt.predict_scores(&sig_te, &model.w).unwrap().len()
                });
            } else {
                println!("(skipping PJRT predict bench — run `make artifacts`)");
            }
        }
    }

    bench.write_csv("results/bench_testing.csv").ok();
}
