//! Estimator evaluation cost: R̂_b / â_vw / â_rp per pair, the b-bit+VW
//! combination of §8, and the Gram-row cost that gates kernel SVM (§5.1).

use bbml::benchkit::{black_box, Bencher};
use bbml::hashing::bbit::{pack_lowest_bits, BbitSignatureMatrix};
use bbml::hashing::estimators::{estimate_r_bbit, estimate_r_bbit_vw};
use bbml::hashing::minwise::MinwiseHasher;
use bbml::hashing::projections::{ProjectionKind, RandomProjection};
use bbml::hashing::vw::VwHasher;

fn main() {
    let mut bench = Bencher::new();
    let d: u64 = 1 << 24;
    let s1: Vec<u64> = (0..300u64).map(|i| i * 7919).collect();
    let s2: Vec<u64> = (150..450u64).map(|i| i * 7919).collect();

    for k in [200usize, 500] {
        let h = MinwiseHasher::new(d, k, 1);
        let z1_full = h.signature(&s1);
        let z2_full = h.signature(&s2);
        for b in [1u32, 8, 16] {
            let z1 = pack_lowest_bits(&z1_full, b);
            let z2 = pack_lowest_bits(&z2_full, b);
            bench.bench(&format!("estimate/r_bbit k={k} b={b}"), || {
                black_box(estimate_r_bbit(&z1, &z2, 300, 300, d, b))
            });
        }
        // §8: VW on top of b=16 signatures.
        let z1 = pack_lowest_bits(&z1_full, 16);
        let z2 = pack_lowest_bits(&z2_full, 16);
        let vw = VwHasher::new(256 * k, 9);
        bench.bench(&format!("estimate/r_bbit_vw k={k} b=16 m=2^8k"), || {
            black_box(estimate_r_bbit_vw(&z1, &z2, 16, &vw, 300, 300, d))
        });
    }

    // Baselines at matched sample counts.
    let vw = VwHasher::new(512, 3);
    let g1 = vw.hash_binary(&s1);
    let g2 = vw.hash_binary(&s2);
    bench.bench("estimate/vw_inner k=512", || {
        black_box(VwHasher::estimate_inner_product(&g1, &g2))
    });
    let rp = RandomProjection::new(512, ProjectionKind::Rademacher, 3);
    let v1 = rp.project_binary(&s1);
    let v2 = rp.project_binary(&s2);
    bench.bench("estimate/rp_inner k=512", || {
        black_box(RandomProjection::estimate_inner_product(&v1, &v2))
    });

    // Gram-row evaluation over a packed matrix (kernel SVM's unit of work).
    // Rows are built through the batched engine with one reused buffer.
    let mut m = BbitSignatureMatrix::new(200, 8);
    let h = MinwiseHasher::new(d, 200, 5);
    let mut sig_buf = Vec::new();
    for i in 0..512u64 {
        let set: Vec<u64> = (i..i + 200).map(|x| x * 131).collect();
        h.signature_batch_into(&set, &mut sig_buf);
        m.push_full_row(&sig_buf, 1.0);
    }
    bench.bench("gram/row512 match_count k=200 b=8", || {
        let mut acc = 0usize;
        for j in 0..m.n() {
            acc += m.match_count(0, j);
        }
        black_box(acc)
    });

    bench.write_csv("results/bench_estimators.csv").ok();
}
