//! The paper's headline experiment as a benchmark: every hashing scheme at
//! matched storage, accuracy vs storage bits, through the unified
//! pipeline + trainer.
//!
//! Records `results/BENCH_schemes.json` — one flat object with, per
//! scheme × storage point, the storage bits, sample width, test accuracy
//! and hash/train wall-clock — the machine-readable evidence behind the
//! §6–§8 comparison (b-bit minwise dominating at equal storage, VW
//! beating the projections, bbit_vw trading accuracy for a small dense
//! model).
//!
//! Run with `BBML_BENCH_FAST=1` for a CI-sized smoke pass.

use std::time::Instant;

use bbml::benchkit::Bencher;
use bbml::coordinator::pipeline::{sketch_dataset, PipelineOptions};
use bbml::coordinator::report;
use bbml::coordinator::trainer::{evaluate_sketch, train_sketch, Backend};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{matched_dense_k, FeatureMapSpec, Scheme};

fn main() {
    let fast = std::env::var("BBML_BENCH_FAST").ok().as_deref() == Some("1");
    let n_docs = if fast { 400 } else { 2_000 };
    let cfg = SynthConfig {
        n_docs,
        dim: 1 << 22,
        vocab: 10_000,
        mean_len: 60,
        topic_mix: 0.5,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.25, 5);
    let opt = PipelineOptions::default();
    let b = 8u32;
    // Storage points: bbit (k, 8) bits = k·8; dense schemes matched.
    let k_points: &[usize] = if fast { &[64] } else { &[64, 128, 256] };

    let mut bench = Bencher::new();
    let mut entries: Vec<(String, String)> = Vec::new();
    entries.push(("n_train".into(), train.n().to_string()));
    entries.push(("n_test".into(), test.n().to_string()));
    entries.push(("backend".into(), report::json_string("svm")));

    for &k in k_points {
        let storage_bits = k * b as usize;
        for scheme in Scheme::ALL {
            let spec = match scheme {
                Scheme::Bbit | Scheme::BbitVw => {
                    FeatureMapSpec::new(scheme, ds.dim(), k, b, 11)
                }
                _ => FeatureMapSpec::new(scheme, ds.dim(), matched_dense_k(k, b), 0, 11),
            };
            let map = spec.build();
            assert_eq!(map.layout().storage_bits_per_example(), storage_bits);

            let label = format!("{}@{}b", scheme.name(), storage_bits);
            let t_hash = Instant::now();
            let mut hashed = None;
            bench.bench_once(&format!("schemes/hash/{label}"), || {
                hashed = Some((
                    sketch_dataset(&train, map.as_ref(), &opt).0,
                    sketch_dataset(&test, map.as_ref(), &opt).0,
                ));
            });
            let hash_secs = t_hash.elapsed().as_secs_f64();
            let (sk_tr, sk_te) = hashed.unwrap();

            let mut out = None;
            bench.bench_once(&format!("schemes/train/{label}"), || {
                out = Some(
                    train_sketch(&sk_tr, Backend::SvmDcd, 1.0, 3, None, None).unwrap(),
                );
            });
            let out = out.unwrap();
            let (acc, _) = evaluate_sketch(&out.model, &sk_te);
            println!(
                "{label:>24}: acc {acc:.4} (k={}, hash {hash_secs:.2}s, train {:.2}s)",
                map.layout().k(),
                out.train_time.as_secs_f64()
            );
            let key = format!("{}_{storage_bits}", scheme.name());
            entries.push((format!("{key}_bits"), storage_bits.to_string()));
            entries.push((format!("{key}_k"), map.layout().k().to_string()));
            entries.push((format!("{key}_acc"), format!("{acc:.6}")));
            entries.push((format!("{key}_hash_secs"), format!("{hash_secs:.6}")));
            entries.push((
                format!("{key}_train_secs"),
                format!("{:.6}", out.train_time.as_secs_f64()),
            ));
        }
    }

    // Accuracy-vs-storage record (the figure data) + timing stats.
    let refs: Vec<(&str, String)> = entries
        .iter()
        .map(|(key, value)| (key.as_str(), value.clone()))
        .collect();
    report::write_json_object(std::path::Path::new("results/BENCH_schemes.json"), &refs)
        .unwrap();
    bench.write_json("results/BENCH_schemes_timing.json").unwrap();
    println!("wrote results/BENCH_schemes.json");
}
