//! Hashing/preprocessing throughput (paper §9: "the preprocessing step …
//! requires only one scan of the data" and Figure 3/7's hashing-cost
//! context). Covers minwise signatures across k, the sharded pipeline
//! scaling across threads, and the VW/CM/projection baselines' transform
//! cost.

use bbml::benchkit::{black_box, Bencher};
use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::minwise::MinwiseHasher;
use bbml::hashing::projections::{ProjectionKind, RandomProjection};
use bbml::hashing::vw::{CountMinSketch, VwHasher};

fn main() {
    let mut b = Bencher::new();
    let cfg = SynthConfig {
        n_docs: 2_000,
        dim: 1 << 24,
        vocab: 30_000,
        mean_len: 120,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let doc: Vec<u64> = ds.row(0).to_vec();
    println!(
        "workload: {} docs, avg nnz {:.0}, doc[0] nnz {}",
        ds.n(),
        ds.avg_nnz(),
        doc.len()
    );

    // --- single-document signature cost across k --------------------------
    // `batched` = the one-pass k-lane engine (the production path);
    // `scalar(seed)` = the per-permutation reference scan, kept for the
    // before/after contrast.
    for k in [30usize, 200, 500] {
        let h = MinwiseHasher::new(cfg.dim, k, 1);
        let mut buf = Vec::new();
        b.bench(&format!("minwise/signature_batched/k={k}"), || {
            h.signature_batch_into(black_box(&doc), &mut buf);
            buf.len()
        });
        b.bench(&format!("minwise/signature_scalar(seed)/k={k}"), || {
            h.signature_scalar_into(black_box(&doc), &mut buf);
            buf.len()
        });
    }

    // --- baselines' per-document transform cost ---------------------------
    let vw = VwHasher::new(1 << 12, 3);
    b.bench("vw/hash_binary/k=4096", || vw.hash_binary(black_box(&doc)));
    b.bench("vw/hash_binary_sparse/k=4096", || {
        vw.hash_binary_sparse(black_box(&doc))
    });
    let cm = CountMinSketch::new(1 << 12, 1, 3);
    b.bench("cm/sketch_binary/k=4096", || cm.sketch_binary(black_box(&doc)));
    let rp = RandomProjection::new(64, ProjectionKind::Rademacher, 3);
    b.bench("rp/project_binary/k=64", || rp.project_binary(black_box(&doc)));

    // --- pipeline scaling --------------------------------------------------
    for threads in [1usize, 2, 4, 8] {
        let opt = PipelineOptions {
            threads,
            ..Default::default()
        };
        b.bench_once(&format!("pipeline/hash_dataset/threads={threads}"), || {
            hash_dataset(&ds, 200, 8, 7, &opt)
        });
    }

    b.write_csv("results/bench_hashing.csv").ok();
}
