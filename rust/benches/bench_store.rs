//! End-to-end out-of-core benchmark: hash a synthetic corpus into an
//! on-disk shard store (raw and gzip framing) and train a linear model
//! from the shard stream, against the in-memory pipeline as the baseline.
//!
//! Records `results/BENCH_store.json` (via `benchkit::write_json`) — the
//! machine-readable evidence that spilling to disk costs a bounded factor
//! over the in-memory hash pass while memory stays flat.
//!
//! Run with `BBML_BENCH_FAST=1` for a CI-sized smoke pass.

use bbml::benchkit::{black_box, Bencher};
use bbml::coordinator::pipeline::{hash_corpus, hash_corpus_to_store, PipelineOptions};
use bbml::coordinator::stream_train::{
    evaluate_stream, train_stream, StreamAlgo, StreamTrainOptions,
};
use bbml::data::synth::{CorpusSampler, SynthConfig};
use bbml::store::SigShardStore;

fn main() {
    let fast = std::env::var("BBML_BENCH_FAST").ok().as_deref() == Some("1");
    let n_docs = if fast { 400 } else { 4_000 };
    let cfg = SynthConfig {
        n_docs,
        dim: 1 << 22,
        vocab: 10_000,
        mean_len: 80,
        topic_mix: 0.4,
        ..Default::default()
    };
    let sampler = CorpusSampler::new(cfg);
    let (k, b, seed) = (64usize, 8u32, 7u64);
    let opt = PipelineOptions {
        chunk: 256,
        ..Default::default()
    };
    let base = std::env::temp_dir().join(format!("bbml_bench_store_{}", std::process::id()));
    std::fs::remove_dir_all(&base).ok();

    let mut bench = Bencher::new();

    // Baseline: the in-memory pipeline sink.
    bench.bench_once(&format!("store/hash_in_memory n={n_docs}"), || {
        black_box(hash_corpus(&sampler, n_docs, k, b, seed, &opt))
    });

    // The spill sinks: raw framing vs gzip framing.
    for gzip in [false, true] {
        let label = if gzip { "gzip" } else { "raw" };
        let dir = base.join(label);
        bench.bench_once(&format!("store/hash_to_store/{label} n={n_docs}"), || {
            hash_corpus_to_store(&sampler, n_docs, k, b, seed, &opt, &dir, gzip).unwrap()
        });
    }

    // Out-of-core training over the raw store.
    let store = SigShardStore::open(&base.join("raw")).unwrap();
    println!(
        "store: {} shards, {} rows, {:.2} MB packed / {:.2} MB on disk",
        store.n_shards(),
        store.n_rows(),
        store.packed_bytes() as f64 / 1e6,
        store.stored_bytes() as f64 / 1e6
    );
    for algo in [StreamAlgo::Pegasos, StreamAlgo::LogRegSgd] {
        let topt = StreamTrainOptions {
            algo,
            epochs: if fast { 2 } else { 5 },
            ..Default::default()
        };
        let mut report = None;
        bench.bench_once(
            &format!("store/train_stream/{} epochs={}", algo.name(), topt.epochs),
            || report = Some(train_stream(&store, &topt).unwrap()),
        );
        let report = report.unwrap();
        let (acc, _) = evaluate_stream(&report.model, &store, topt.prefetch).unwrap();
        println!(
            "  {}: acc {:.4}, peak resident {} of {} rows",
            algo.name(),
            acc,
            report.peak_resident_rows,
            store.n_rows()
        );
    }

    bench.write_json("results/BENCH_store.json").unwrap();
    std::fs::remove_dir_all(&base).ok();
}
