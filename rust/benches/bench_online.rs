//! Online-trainer benchmarks: what the streaming loop costs per row and
//! what a snapshot publish costs per swap.
//!
//! Three questions, matching the subsystem's serving-loop shape:
//!
//! 1. **Ingest rows/s** — the full live-row path (drift gauges → encode →
//!    SGD step → epoch-0 spool flush), the number an operator sizes a
//!    producer against.
//! 2. **Drift gauge overhead** — `observe_row` alone, to show the
//!    Count-Min watch is a small slice of (1).
//! 3. **Snapshot publish latency** — temp+rename artifact + pointer, the
//!    stall between "trainer decides to publish" and "`serve --watch`
//!    can see it" (benchkit records the full percentile set).
//!
//! Results land in `results/BENCH_online.{json,csv}`. Set
//! `BBML_BENCH_FAST=1` for a CI-sized run.

use bbml::benchkit::{black_box, Bencher};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{FeatureMapSpec, Scheme};
use bbml::online::{DriftStats, OnlineOptions, OnlineSession, SnapshotPublisher};
use bbml::coordinator::StreamAlgo;
use bbml::rng::Xoshiro256;
use bbml::solvers::LinearModel;
use bbml::store::ModelArtifact;

fn main() {
    let mut b = Bencher::new();
    let fast = std::env::var("BBML_BENCH_FAST").ok().as_deref() == Some("1");
    let n_rows = if fast { 256 } else { 2048 };

    // The paper's sweet spot (k=64, b=4) over a webspam-shaped stream.
    let dim = 1u64 << 24;
    let spec = FeatureMapSpec::new(Scheme::Bbit, dim, 64, 4, 42);
    let cfg = SynthConfig {
        n_docs: n_rows,
        dim,
        vocab: 20_000,
        mean_len: 60,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let rows: Vec<(f32, Vec<u64>)> = (0..ds.n())
        .map(|i| (ds.label(i), ds.row(i).to_vec()))
        .collect();
    println!(
        "workload: {} rows, avg nnz {:.1}, k=64 b=4, dim 2^24",
        rows.len(),
        ds.avg_nnz()
    );

    // --- 1. full ingest path (drift + encode + step + spool) -------------
    // A declared epoch far longer than the bench ever feeds keeps the
    // session in epoch 0 throughout, so every iteration pays the same
    // live-row cost (including the spool's shard flushes).
    let snap_dir = std::env::temp_dir().join(format!("bbml_bench_online_{}", std::process::id()));
    std::fs::remove_dir_all(&snap_dir).ok();
    let mut sess = OnlineSession::new(
        spec.clone(),
        OnlineOptions {
            algo: StreamAlgo::Pegasos,
            c: 1.0,
            epochs: 1,
            rows_per_epoch: 1 << 30,
            average: false,
            snapshot_every: 0,
            chunk: 512,
        },
        &snap_dir,
        None,
    )
    .unwrap();
    b.bench_throughput("online/ingest k=64 b=4", rows.len() as u64, || {
        for (label, row) in &rows {
            sess.ingest(*label, row).unwrap();
        }
        black_box(sess.steps());
    });

    // --- 2. the drift gauges alone ---------------------------------------
    let mut drift = DriftStats::new(dim, 1024);
    b.bench_throughput("online/drift-observe", rows.len() as u64, || {
        for (_, row) in &rows {
            drift.observe_row(row);
        }
        black_box(drift.rows());
    });

    // --- 3. snapshot publish latency -------------------------------------
    // The artifact a k=64/b=4 trainer publishes: 1024 weights + spec.
    let n_weights = spec.layout().train_dim();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let w: Vec<f32> = (0..n_weights).map(|_| rng.gen_f32() - 0.5).collect();
    let artifact = ModelArtifact::new(
        spec,
        LinearModel {
            w,
            iters: 1,
            objective: 0.0,
        },
    )
    .unwrap();
    let pub_dir = snap_dir.join("publish");
    let mut publisher = SnapshotPublisher::new(&pub_dir, 0).unwrap();
    b.bench("online/snapshot-publish", || {
        let snap = publisher.publish(&artifact).unwrap();
        black_box(snap.model_crc32);
        // Keep the history directory bounded across iterations.
        std::fs::remove_file(&snap.path).ok();
    });

    std::fs::remove_dir_all(&snap_dir).ok();
    b.write_json("results/BENCH_online.json").unwrap();
    b.write_csv("results/BENCH_online.csv").unwrap();
}
