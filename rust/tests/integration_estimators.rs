//! Statistical integration: every estimator family on one shared workload,
//! validated against the paper's closed-form means and variances.

use bbml::hashing::bbit::pack_lowest_bits;
use bbml::hashing::estimators::{estimate_a_from_r, estimate_r_bbit, p_hat};
use bbml::hashing::minwise::MinwiseHasher;
use bbml::hashing::projections::{ProjectionKind, RandomProjection};
use bbml::hashing::vw::VwHasher;
use bbml::proptest_mini::{check, gen};
use bbml::theory::pb::BbitConstants;
use bbml::theory::variance::{var_bbit, var_minwise, var_rp, var_vw, PairMoments};

/// One pair of sets with known statistics, shared by all the tests.
struct Pair {
    s1: Vec<u64>,
    s2: Vec<u64>,
    f1: u64,
    f2: u64,
    a: u64,
    r: f64,
    d: u64,
}

fn the_pair() -> Pair {
    let d = 1 << 20;
    let s1: Vec<u64> = (0..300).collect();
    let s2: Vec<u64> = (150..450).collect();
    Pair {
        f1: 300,
        f2: 300,
        a: 150,
        r: 150.0 / 450.0,
        d,
        s1,
        s2,
    }
}

#[test]
fn every_estimator_is_consistent_on_the_same_pair() {
    let p = the_pair();
    // --- minwise (eq. 2/3) ---
    let k = 256;
    let h = MinwiseHasher::new(p.d, k, 1);
    let r_mw = MinwiseHasher::estimate_resemblance(&h.signature(&p.s1), &h.signature(&p.s2));
    let std_mw = var_minwise(p.r, k).sqrt();
    assert!((r_mw - p.r).abs() < 5.0 * std_mw, "minwise {r_mw} vs {}", p.r);

    // --- b-bit (eq. 5/6) ---
    for b in [1u32, 4, 8] {
        let z1 = pack_lowest_bits(&h.signature(&p.s1), b);
        let z2 = pack_lowest_bits(&h.signature(&p.s2), b);
        let r_b = estimate_r_bbit(&z1, &z2, p.f1, p.f2, p.d, b);
        let c = BbitConstants::from_cardinalities(p.f1, p.f2, p.d, b);
        let std_b = var_bbit(&c, p.r, k).sqrt();
        assert!(
            (r_b - p.r).abs() < 5.0 * std_b,
            "b={b}: {r_b} vs {} (std {std_b})",
            p.r
        );
        // Inner product recovery (Appendix C).
        let a_hat = estimate_a_from_r(r_b, p.f1, p.f2);
        assert!((a_hat - p.a as f64).abs() < 60.0, "â = {a_hat}");
    }

    // --- VW (Lemma 1) ---
    let vw = VwHasher::new(512, 7);
    let a_vw = VwHasher::estimate_inner_product(
        &vw.hash_binary(&p.s1),
        &vw.hash_binary(&p.s2),
    );
    let m = PairMoments::binary(p.f1, p.f2, p.a);
    let std_vw = var_vw(&m, 1.0, 512).sqrt();
    assert!(
        (a_vw - p.a as f64).abs() < 5.0 * std_vw,
        "vw {a_vw} vs {} (std {std_vw})",
        p.a
    );

    // --- random projections (eq. 13/14) ---
    let rp = RandomProjection::new(512, ProjectionKind::Rademacher, 9);
    let a_rp = RandomProjection::estimate_inner_product(
        &rp.project_binary(&p.s1),
        &rp.project_binary(&p.s2),
    );
    let std_rp = var_rp(&m, 1.0, 512).sqrt();
    assert!((a_rp - p.a as f64).abs() < 5.0 * std_rp, "rp {a_rp}");
}

#[test]
fn bbit_beats_vw_at_equal_storage_empirically() {
    // The G_vw story end-to-end: at the same *bit* budget, b-bit hashing
    // estimates a with lower squared error than VW.
    let p = the_pair();
    let budget_bits = 8 * 256; // 2048 bits per example
    let b = 8u32;
    let k_bbit = (budget_bits / b as usize).min(256); // 256 samples × 8 bits
    let k_vw = budget_bits / 32; // 64 samples × 32 bits
    let reps = 300;
    let (mut se_b, mut se_vw) = (0.0, 0.0);
    for seed in 0..reps {
        let h = MinwiseHasher::new(p.d, k_bbit, 100 + seed);
        let z1 = pack_lowest_bits(&h.signature(&p.s1), b);
        let z2 = pack_lowest_bits(&h.signature(&p.s2), b);
        let r_b = estimate_r_bbit(&z1, &z2, p.f1, p.f2, p.d, b);
        let a_b = estimate_a_from_r(r_b, p.f1, p.f2);
        se_b += (a_b - p.a as f64).powi(2);

        let vw = VwHasher::new(k_vw, 500_000 + seed);
        let a_v = VwHasher::estimate_inner_product(
            &vw.hash_binary(&p.s1),
            &vw.hash_binary(&p.s2),
        );
        se_vw += (a_v - p.a as f64).powi(2);
    }
    let (mse_b, mse_vw) = (se_b / reps as f64, se_vw / reps as f64);
    assert!(
        mse_vw > 3.0 * mse_b,
        "expected b-bit ≫ VW at equal storage: MSE {mse_b:.2} vs {mse_vw:.2}"
    );
}

#[test]
fn prop_bbit_estimator_is_calibrated_across_random_pairs() {
    check("R̂_b calibration", 15, |rng| {
        let d = 1 << 18;
        let f1 = 100 + rng.gen_range(200) as usize;
        let f2 = 100 + rng.gen_range(200) as usize;
        let a = rng.gen_range(f1.min(f2) as u64 + 1) as usize;
        let (s1, s2) = gen::overlapping_sets(rng, d, f1, f2, a);
        let r = a as f64 / (f1 + f2 - a) as f64;
        let k = 200;
        let b = 8;
        let h = MinwiseHasher::new(d, k, rng.next_u64());
        let z1 = pack_lowest_bits(&h.signature(&s1), b);
        let z2 = pack_lowest_bits(&h.signature(&s2), b);
        let r_hat = estimate_r_bbit(&z1, &z2, f1 as u64, f2 as u64, d, b);
        let c = BbitConstants::from_cardinalities(f1 as u64, f2 as u64, d, b);
        let std = var_bbit(&c, r, k).sqrt();
        assert!(
            (r_hat - r).abs() < 6.0 * std + 0.02,
            "R={r:.3} R̂={r_hat:.3} std={std:.4} (f1={f1} f2={f2} a={a})"
        );
    });
}

#[test]
fn prop_p_hat_matches_expected_collision_rate() {
    check("P̂_b vs theory", 10, |rng| {
        let d = 1 << 16;
        let (s1, s2) = gen::overlapping_sets(rng, d, 150, 150, 75);
        let r = 75.0 / 225.0;
        let b = 2u32;
        let k = 400;
        let h = MinwiseHasher::new(d, k, rng.next_u64());
        let z1 = pack_lowest_bits(&h.signature(&s1), b);
        let z2 = pack_lowest_bits(&h.signature(&s2), b);
        let observed = p_hat(&z1, &z2);
        let expect = BbitConstants::from_cardinalities(150, 150, d, b).p_b(r);
        // Binomial std for k samples.
        let std = (expect * (1.0 - expect) / k as f64).sqrt();
        assert!(
            (observed - expect).abs() < 6.0 * std,
            "P̂ {observed:.4} vs P {expect:.4}"
        );
    });
}
