//! End-to-end serving tests over real loopback sockets: bit-identity with
//! offline `predict_artifact`, atomic hot swap under concurrent hammering
//! (ISSUE-8's no-drop / no-mix acceptance), swap validation, and graceful
//! shutdown draining.

use std::collections::HashMap;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::thread::JoinHandle;

use bbml::coordinator::pipeline::PipelineOptions;
use bbml::coordinator::trainer::predict_artifact;
use bbml::data::sparse::SparseBinaryDataset;
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{FeatureMapSpec, Scheme};
use bbml::rng::Xoshiro256;
use bbml::serve::{serve, ModelSlot, ScoreClient, ServeOptions, ServeStats, ServedModel};
use bbml::solvers::LinearModel;
use bbml::store::ModelArtifact;

const DIM: u64 = 1 << 18;

fn artifact(scheme: Scheme, k: usize, seed: u64) -> ModelArtifact {
    let spec = FeatureMapSpec::new(scheme, DIM, k, 4, seed);
    let n = spec.layout().train_dim();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let w: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
    ModelArtifact::new(
        spec,
        LinearModel {
            w,
            iters: 1,
            objective: 0.0,
        },
    )
    .unwrap()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bbml_serve_{}_{}", name, std::process::id()))
}

fn corpus(n_docs: usize) -> SparseBinaryDataset {
    generate_corpus(&SynthConfig {
        n_docs,
        dim: DIM,
        vocab: 400,
        mean_len: 30,
        ..Default::default()
    })
}

fn rows_of(ds: &SparseBinaryDataset) -> Vec<Vec<u64>> {
    (0..ds.n()).map(|i| ds.row(i).to_vec()).collect()
}

fn offline_bits(art: &ModelArtifact, ds: &SparseBinaryDataset) -> Vec<u64> {
    let opt = PipelineOptions {
        threads: 1,
        ..Default::default()
    };
    let out = predict_artifact(art, ds, &opt).unwrap();
    out.scores.iter().map(|s| s.to_bits()).collect()
}

/// Bind port 0, launch the server on a background thread, and hand back
/// the pieces a test needs: address, slot/stats handles, the stop flag,
/// and the join handle (joins clean after a `Shutdown` frame).
#[allow(clippy::type_complexity)]
fn start_server(
    model: ServedModel,
    workers: usize,
) -> (
    std::net::SocketAddr,
    Arc<ModelSlot>,
    Arc<ServeStats>,
    Arc<AtomicBool>,
    JoinHandle<()>,
) {
    let slot = Arc::new(ModelSlot::new(model));
    let stats = Arc::new(ServeStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = {
        let (slot, stats, stop) = (Arc::clone(&slot), Arc::clone(&stats), Arc::clone(&stop));
        std::thread::spawn(move || {
            let opt = ServeOptions {
                workers,
                ..Default::default()
            };
            serve(listener, slot, stats, &opt, stop).unwrap();
        })
    };
    (addr, slot, stats, stop, handle)
}

#[test]
fn served_scores_are_bit_identical_to_offline_predict() {
    // One sparse scheme (the paper's) and one dense baseline: the serving
    // path must reproduce `predict_artifact` bit for bit on both.
    for scheme in [Scheme::Bbit, Scheme::Vw] {
        let path = tmp(&format!("ident_{scheme}.bbm"));
        artifact(scheme, 16, 7).save(&path).unwrap();
        let art = ModelArtifact::load(&path).unwrap();
        let ds = corpus(41);
        let rows = rows_of(&ds);
        let expected = offline_bits(&art, &ds);

        let (addr, _slot, _stats, _stop, handle) =
            start_server(ServedModel::load(&path).unwrap(), 2);
        let mut client = ScoreClient::connect(addr).unwrap();
        let mut got = Vec::with_capacity(rows.len());
        // Odd batch size on purpose: responses must stitch across
        // request boundaries without reordering.
        for batch in rows.chunks(7) {
            let (crc, scores) = client.score(batch).unwrap();
            assert_eq!(crc, ServedModel::load(&path).unwrap().crc32);
            got.extend(scores.iter().map(|s| s.to_bits()));
        }
        assert_eq!(got, expected, "scheme {scheme}: served bits != offline");
        client.shutdown().unwrap();
        handle.join().unwrap();
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn hammer_under_repeated_hot_swap_never_mixes_or_drops() {
    // Two compatible models (same scheme + input domain, different k and
    // weights) swapped back and forth while 4 client threads hammer.
    let (pa, pb) = (tmp("hammer_a.bbm"), tmp("hammer_b.bbm"));
    let art_a = artifact(Scheme::Bbit, 8, 11);
    let art_b = artifact(Scheme::Bbit, 16, 22);
    art_a.save(&pa).unwrap();
    art_b.save(&pb).unwrap();
    let ds = corpus(64);
    let rows = rows_of(&ds);
    let served_a = ServedModel::load(&pa).unwrap();
    let (crc_a, crc_b) = (served_a.crc32, ServedModel::load(&pb).unwrap().crc32);
    assert_ne!(crc_a, crc_b);
    let mut expected: HashMap<u32, Vec<u64>> = HashMap::new();
    expected.insert(crc_a, offline_bits(&art_a, &ds));
    expected.insert(crc_b, offline_bits(&art_b, &ds));

    // More workers than live connections (4 scorers + 1 swapper): a
    // connection-per-worker pool must never starve the swapper.
    let (addr, slot, stats, _stop, handle) = start_server(served_a, 6);
    const SCORERS: usize = 4;
    const REQS: usize = 50;
    const BATCH: usize = 8;
    const SWAPS: usize = 30;

    std::thread::scope(|s| {
        let rows = &rows;
        let expected = &expected;
        let mut scorers = Vec::new();
        for t in 0..SCORERS {
            scorers.push(s.spawn(move || {
                let mut client = ScoreClient::connect(addr).unwrap();
                let mut answered = 0usize;
                for r in 0..REQS {
                    let start = ((t * 13 + r * BATCH) % (rows.len() - BATCH)).min(rows.len());
                    let batch = &rows[start..start + BATCH];
                    // Every request must be answered (no drops)...
                    let (crc, scores) = client.score(batch).unwrap();
                    // ...by exactly one published model (no mixes):
                    let want = expected
                        .get(&crc)
                        .unwrap_or_else(|| panic!("crc {crc} is neither published model"));
                    let got: Vec<u64> = scores.iter().map(|sc| sc.to_bits()).collect();
                    assert_eq!(got, want[start..start + BATCH], "thread {t} req {r}");
                    answered += 1;
                }
                answered
            }));
        }
        let (pa_ref, pb_ref) = (&pa, &pb);
        let swapper = s.spawn(move || {
            let mut client = ScoreClient::connect(addr).unwrap();
            for i in 0..SWAPS {
                let (path, want) = if i % 2 == 0 {
                    (pb_ref, crc_b)
                } else {
                    (pa_ref, crc_a)
                };
                let crc = client.reload(Some(path.to_str().unwrap())).unwrap();
                assert_eq!(crc, want);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        let answered: usize = scorers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(answered, SCORERS * REQS, "a request was dropped");
        swapper.join().unwrap();
    });

    assert_eq!(slot.swap_count(), SWAPS as u64);
    assert_eq!(stats.requests(), (SCORERS * REQS) as u64);
    assert_eq!(stats.errors(), 0);

    ScoreClient::connect(addr).unwrap().shutdown().unwrap();
    handle.join().unwrap();
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

#[test]
fn incompatible_swap_is_refused_and_serving_continues() {
    let (p_live, p_bad) = (tmp("guard_live.bbm"), tmp("guard_bad.bbm"));
    artifact(Scheme::Bbit, 8, 1).save(&p_live).unwrap();
    artifact(Scheme::Vw, 8, 2).save(&p_bad).unwrap();
    let live_crc = ServedModel::load(&p_live).unwrap().crc32;
    let (addr, slot, _stats, _stop, handle) =
        start_server(ServedModel::load(&p_live).unwrap(), 2);

    let mut client = ScoreClient::connect(addr).unwrap();
    let err = client.reload(Some(p_bad.to_str().unwrap())).unwrap_err();
    assert!(err.to_string().contains("scheme"), "{err}");
    // The refused swap left the live model serving on the same connection.
    let (crc, scores) = client.score(&[vec![1u64, 5, 900]]).unwrap();
    assert_eq!(crc, live_crc);
    assert_eq!(scores.len(), 1);
    assert_eq!(slot.swap_count(), 0);

    client.shutdown().unwrap();
    handle.join().unwrap();
    std::fs::remove_file(&p_live).ok();
    std::fs::remove_file(&p_bad).ok();
}

#[test]
fn bad_rows_get_an_error_frame_and_the_connection_survives() {
    let p = tmp("rows.bbm");
    artifact(Scheme::Bbit, 8, 3).save(&p).unwrap();
    let (addr, _slot, stats, _stop, handle) =
        start_server(ServedModel::load(&p).unwrap(), 2);

    let mut client = ScoreClient::connect(addr).unwrap();
    // Out-of-domain index → Error frame, not a dropped connection.
    let err = client.score(&[vec![DIM]]).unwrap_err();
    assert!(err.to_string().contains("domain"), "{err}");
    // Unsorted row → same.
    let err = client.score(&[vec![5u64, 3]]).unwrap_err();
    assert!(err.to_string().contains("sorted"), "{err}");
    // The connection still scores valid rows afterwards.
    let (_, scores) = client.score(&[vec![3u64, 99]]).unwrap();
    assert_eq!(scores.len(), 1);
    assert_eq!(stats.errors(), 2);
    assert_eq!(stats.requests(), 1);

    client.shutdown().unwrap();
    handle.join().unwrap();
    std::fs::remove_file(&p).ok();
}

#[test]
fn stats_frame_and_graceful_shutdown_drain() {
    let p = tmp("stats.bbm");
    artifact(Scheme::Bbit, 8, 5).save(&p).unwrap();
    let (addr, slot, stats, _stop, handle) =
        start_server(ServedModel::load(&p).unwrap(), 2);

    let mut client = ScoreClient::connect(addr).unwrap();
    for _ in 0..3 {
        client.score(&[vec![1u64, 2, 3], vec![10, 20]]).unwrap();
    }
    let json = client.stats().unwrap();
    for key in [
        "\"requests\": 3",
        "\"rows\": 6",
        "\"swap_count\": 0",
        "\"p50_us\":",
        "\"p95_us\":",
        "\"p99_us\":",
        "\"rows_per_sec\":",
        "\"queue_depth\":",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }

    // Graceful shutdown: acknowledged, server drains and joins, and the
    // gauges survive for the final report.
    client.shutdown().unwrap();
    handle.join().unwrap();
    assert_eq!(stats.requests(), 3);
    assert_eq!(stats.rows(), 6);
    assert_eq!(slot.swap_count(), 0);
    // The drained listener is gone: a fresh connect must fail.
    assert!(ScoreClient::connect(addr).is_err());
    std::fs::remove_file(&p).ok();
}
