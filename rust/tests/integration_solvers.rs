//! Cross-solver integration: all solvers on the same hashed workload must
//! broadly agree; the kernel SVM path must match the linear path on the
//! expanded features (Theorem 2 says they optimize over the same kernel).

use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::solvers::kernel_svm::{train_kernel_svm, BbitKernel, KernelSvmOptions};
use bbml::solvers::linear_svm::{train_svm, SvmLoss, SvmOptions};
use bbml::solvers::logreg::train_logreg;
use bbml::solvers::logreg::LogRegOptions;
use bbml::solvers::{BinaryFeatures, ExpandedView};

fn workload() -> (
    bbml::hashing::bbit::BbitSignatureMatrix,
    bbml::hashing::bbit::BbitSignatureMatrix,
) {
    let cfg = SynthConfig {
        n_docs: 500,
        dim: 1 << 22,
        vocab: 10_000,
        mean_len: 80,
        topic_mix: 0.3,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.25, 3);
    let opt = PipelineOptions::default();
    (
        hash_dataset(&train, 96, 8, 13, &opt).0,
        hash_dataset(&test, 96, 8, 13, &opt).0,
    )
}

#[test]
fn all_solvers_agree_on_easy_workload() {
    let (tr, te) = workload();
    let view_tr = ExpandedView::new(&tr);
    let view_te = ExpandedView::new(&te);

    let svm = train_svm(
        &view_tr,
        &SvmOptions {
            c: 1.0,
            loss: SvmLoss::L2,
            ..Default::default()
        },
    );
    let lr = train_logreg(
        &view_tr,
        &LogRegOptions {
            c: 1.0,
            ..Default::default()
        },
    );
    let acc_svm = svm.accuracy(&view_te);
    let acc_lr = lr.accuracy(&view_te);
    assert!(acc_svm > 0.9, "svm {acc_svm}");
    assert!(acc_lr > 0.9, "logreg {acc_lr}");
    assert!((acc_svm - acc_lr).abs() < 0.08, "{acc_svm} vs {acc_lr}");
}

#[test]
fn kernel_svm_on_bbit_kernel_matches_linear_on_expansion() {
    // Theorem 2: the b-bit kernel IS the inner product of the expansion
    // (up to the 1/k scale). Both solvers should classify alike.
    let (tr, te) = workload();
    let view_tr = ExpandedView::new(&tr);
    let linear = train_svm(
        &view_tr,
        &SvmOptions {
            c: 1.0,
            loss: SvmLoss::L1,
            ..Default::default()
        },
    );
    let kernel = BbitKernel { sigs: &tr };
    let kmodel = train_kernel_svm(
        &kernel,
        &KernelSvmOptions {
            // K = match/k rescales the kernel by 1/k; compensate in C so
            // the two solve the same optimization problem.
            c: 96.0,
            ..Default::default()
        },
    );
    // Evaluate the kernel model on test rows via cross match counts.
    let tr_rows: Vec<Vec<u16>> = (0..tr.n()).map(|j| tr.row(j)).collect();
    let mut te_row = vec![0u16; te.k()];
    let mut agree = 0usize;
    let mut kernel_correct = 0usize;
    let view_te = ExpandedView::new(&te);
    for t in 0..te.n() {
        te.unpack_row_into(t, &mut te_row);
        let s_kernel = kmodel.score_with(|j| {
            te_row.iter().zip(&tr_rows[j]).filter(|(a, b)| a == b).count() as f64 / 96.0
        });
        let pred_kernel = s_kernel >= 0.0;
        let pred_linear = linear.score(&view_te, t) >= 0.0;
        if pred_kernel == pred_linear {
            agree += 1;
        }
        if pred_kernel == (te.label(t) > 0.0) {
            kernel_correct += 1;
        }
    }
    let agreement = agree as f64 / te.n() as f64;
    let acc = kernel_correct as f64 / te.n() as f64;
    assert!(acc > 0.9, "kernel-svm accuracy {acc}");
    assert!(agreement > 0.9, "linear/kernel agreement {agreement}");
}

#[test]
fn c_sweep_shows_regularization_path() {
    // Tiny C shrinks the model (‖w‖ → 0) and must never *beat* a
    // well-tuned C; the paper's "best performance usually at C >= 1".
    let (tr, te) = workload();
    let view_tr = ExpandedView::new(&tr);
    let view_te = ExpandedView::new(&te);
    let model_at = |c: f64| {
        train_svm(
            &view_tr,
            &SvmOptions {
                c,
                loss: SvmLoss::L2,
                ..Default::default()
            },
        )
    };
    let tiny = model_at(1e-5);
    let good = model_at(1.0);
    let norm = |m: &bbml::solvers::LinearModel| -> f64 {
        m.w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    };
    assert!(
        norm(&tiny) < 0.2 * norm(&good),
        "C=1e-5 ‖w‖ {} should be far smaller than C=1 ‖w‖ {}",
        norm(&tiny),
        norm(&good)
    );
    let (acc_tiny, acc_good) = (tiny.accuracy(&view_te), good.accuracy(&view_te));
    assert!(
        acc_good >= acc_tiny - 0.01,
        "C=1 ({acc_good}) must not lose to C=1e-5 ({acc_tiny})"
    );
}
