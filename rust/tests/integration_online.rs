//! Online-learning integration: the subsystem's acceptance criteria.
//!
//! * A replayed finite stream (shuffle is always off online) trains
//!   **bit-identically** to batch `train_stream` over the same corpus —
//!   weights, objective and `weights_crc32` — across schemes and both
//!   SGD algorithms.
//! * A published snapshot picked up through the `latest.model` pointer by
//!   the serving slot scores bit-identically to offline `predict`.
//! * A session killed mid-stream and resumed from its `BBOCKPT`
//!   checkpoint finishes bit-identical to an uninterrupted one.
//! * The Count-Min conservative update is sandwiched between the true
//!   count and the plain-update estimate (property test against the
//!   [`CountMin::observe_plain`] oracle).

use std::io::Cursor;
use std::path::PathBuf;

use bbml::coordinator::report::weights_crc32;
use bbml::coordinator::{
    predict_artifact, sketch_dataset_to_store, train_stream, PipelineOptions, StreamAlgo,
    StreamTrainOptions,
};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{FeatureMapSpec, Scheme};
use bbml::online::{CountMin, LineSource, OnlineOptions, OnlineSession, POINTER_NAME};
use bbml::proptest_mini::check;
use bbml::serve::{ModelSlot, ServedModel};
use bbml::store::{ModelArtifact, ModelPointer, SigShardStore};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bbml_ionline_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn corpus_cfg(n: usize) -> SynthConfig {
    SynthConfig {
        n_docs: n,
        dim: 1 << 20,
        vocab: 4_000,
        topic_size: 100,
        mean_len: 40,
        topic_mix: 0.5,
        ..Default::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The corpus as LIBSVM text — the exact byte stream `--from stdin` would
/// consume, written through the same serializer `generate` uses.
fn libsvm_text(ds: &bbml::data::sparse::SparseBinaryDataset, tag: &str) -> String {
    let path = std::env::temp_dir().join(format!(
        "bbml_ionline_{}_{}.libsvm",
        tag,
        std::process::id()
    ));
    bbml::data::libsvm::write_libsvm(ds, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

#[test]
fn replayed_stream_is_bit_identical_to_batch_train_stream() {
    // THE bit-identity contract: same rows, same declared epoch length,
    // shuffle off ⇒ the streaming trainer IS the batch trainer, bit for
    // bit — weights, objective, fingerprint. Across packed (bbit), dense
    // hashed (vw) and dense projected (proj_sparse) schemes, and both
    // stream algorithms.
    let n = 120;
    let ds = generate_corpus(&corpus_cfg(n));
    let text = libsvm_text(&ds, "bitid");
    let popt = PipelineOptions {
        threads: 2,
        chunk: 30,
        queue: 2,
    };
    for (scheme, k, algo) in [
        (Scheme::Bbit, 16, StreamAlgo::Pegasos),
        (Scheme::Bbit, 16, StreamAlgo::LogRegSgd),
        (Scheme::Vw, 256, StreamAlgo::Pegasos),
        (Scheme::ProjSparse, 64, StreamAlgo::LogRegSgd),
    ] {
        let spec = FeatureMapSpec::new(scheme, ds.dim(), k, 4, 9);
        let store_dir = tmp_dir(&format!("bitid_store_{}_{}", scheme.name(), algo.name()));
        let map = spec.build();
        sketch_dataset_to_store(&ds, map.as_ref(), scheme, &popt, &store_dir, false).unwrap();
        let store = SigShardStore::open(&store_dir).unwrap();
        assert_eq!(store.n_rows(), n);

        let batch = train_stream(
            &store,
            &StreamTrainOptions {
                algo,
                c: 1.0,
                epochs: 2,
                seed: 0,
                shuffle: false,
                row_shuffle: false,
                prefetch: 3,
                average: true,
            },
        )
        .unwrap();

        let snap_dir = tmp_dir(&format!("bitid_snap_{}_{}", scheme.name(), algo.name()));
        let mut sess = OnlineSession::new(
            spec,
            OnlineOptions {
                algo,
                c: 1.0,
                epochs: 2,
                rows_per_epoch: n,
                average: true,
                snapshot_every: 0,
                chunk: 30,
            },
            &snap_dir,
            None,
        )
        .unwrap();
        let mut src = LineSource::new(Cursor::new(text.clone()), ds.dim());
        let online = sess.run(&mut src).unwrap();

        assert!(online.completed, "{scheme}/{}", algo.name());
        assert_eq!(online.rows_ingested, n as u64);
        assert_eq!(online.rows_stepped, 2 * n as u64, "epoch 1 replays the spool");
        assert_eq!(
            bits(&online.model.w),
            bits(&batch.model.w),
            "{scheme}/{}: streamed weights must be the batch weights",
            algo.name()
        );
        assert_eq!(
            online.model.objective.to_bits(),
            batch.model.objective.to_bits(),
            "{scheme}/{}: objective bits",
            algo.name()
        );
        assert_eq!(
            weights_crc32(&online.model.w),
            weights_crc32(&batch.model.w)
        );
        std::fs::remove_dir_all(&store_dir).ok();
        std::fs::remove_dir_all(&snap_dir).ok();
    }
}

#[test]
fn published_snapshot_serves_bit_identical_scores() {
    // Stream → snapshot → pointer → serving slot → scores, against
    // offline predict over an artifact assembled from the same report.
    let ds = generate_corpus(&corpus_cfg(90));
    let text = libsvm_text(&ds, "serve");
    let spec = FeatureMapSpec::new(Scheme::Bbit, ds.dim(), 16, 4, 7);
    let snap_dir = tmp_dir("serve_snap");
    let mut sess = OnlineSession::new(
        spec.clone(),
        OnlineOptions {
            algo: StreamAlgo::Pegasos,
            c: 1.0,
            epochs: 1,
            rows_per_epoch: 90,
            average: true,
            snapshot_every: 32,
            chunk: 16,
        },
        &snap_dir,
        None,
    )
    .unwrap();
    let mut src = LineSource::new(Cursor::new(text), ds.dim());
    let report = sess.run(&mut src).unwrap();
    assert!(report.completed);
    assert!(
        report.snapshots_published >= 2,
        "cadence 32 over 90 rows plus the final snapshot: {}",
        report.snapshots_published
    );

    // The pointer resolves through the serving loader and carries the
    // final weights.
    let served = ServedModel::load(&snap_dir.join(POINTER_NAME)).unwrap();
    assert_eq!(bits(&served.artifact.model.w), bits(&report.model.w));
    assert_eq!(served.crc32, weights_crc32(&report.model.w));
    let slot = ModelSlot::new(served);

    // Scores through the slot's artifact ≡ offline predict on an
    // artifact assembled directly from the training report.
    let popt = PipelineOptions::default();
    let offline_art = ModelArtifact::new(spec, report.model.clone()).unwrap();
    let offline = predict_artifact(&offline_art, &ds, &popt).unwrap();
    let via_slot = predict_artifact(&slot.load().artifact, &ds, &popt).unwrap();
    assert_eq!(via_slot.rows, offline.rows);
    let score_bits =
        |s: &[f64]| s.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    assert_eq!(
        score_bits(&via_slot.scores),
        score_bits(&offline.scores),
        "slot-served scores must be the offline scores, bit for bit"
    );

    // The pointer itself records the sequence the report saw last.
    let ptr = ModelPointer::load(&snap_dir.join(POINTER_NAME)).unwrap();
    assert_eq!(Some(ptr.seq), report.last_snapshot.as_ref().map(|s| s.seq));
    std::fs::remove_dir_all(&snap_dir).ok();
}

#[test]
fn kill_and_resume_is_bit_identical_to_uninterrupted() {
    // Feed 37 of 100 rows, "die" (drop the session after its EOF
    // checkpoint), resume from BBOCKPT, feed the remaining 63: the final
    // weights/objective must equal an uninterrupted run's, bit for bit.
    // 37 is deliberately not chunk-aligned (chunk 16): the trailing
    // partial chunk is flushed and checkpointed at EOF.
    let n = 100;
    let ds = generate_corpus(&corpus_cfg(n));
    let text = libsvm_text(&ds, "resume");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), n);
    let head = lines[..37].join("\n") + "\n";
    let tail = lines[37..].join("\n") + "\n";
    let spec = FeatureMapSpec::new(Scheme::Bbit, ds.dim(), 16, 4, 5);
    let opt = OnlineOptions {
        algo: StreamAlgo::Pegasos,
        c: 1.0,
        epochs: 2,
        rows_per_epoch: n,
        average: true,
        snapshot_every: 48,
        chunk: 16,
    };

    // Uninterrupted reference.
    let (snap_full, ckpt_full) = (tmp_dir("res_full"), tmp_dir("res_full_ck"));
    let mut sess = OnlineSession::new(spec.clone(), opt.clone(), &snap_full, Some(&ckpt_full))
        .unwrap();
    let mut src = LineSource::new(Cursor::new(text.clone()), ds.dim());
    let full = sess.run(&mut src).unwrap();
    assert!(full.completed);

    // Interrupted run: part 1 pauses incomplete at EOF…
    let (snap, ckpt) = (tmp_dir("res_cut"), tmp_dir("res_cut_ck"));
    let mut part1 = OnlineSession::new(spec, opt, &snap, Some(&ckpt)).unwrap();
    let mut src = LineSource::new(Cursor::new(head), ds.dim());
    let r1 = part1.run(&mut src).unwrap();
    assert!(!r1.completed, "mid-epoch EOF pauses");
    assert_eq!(r1.rows_ingested, 37);
    drop(part1); // the "kill" — everything live is gone

    // …part 2 rebuilds from the checkpoint and finishes the stream.
    let latest = OnlineSession::checkpoint_latest(&ckpt);
    let mut part2 = OnlineSession::resume(&latest, &snap, Some(&ckpt)).unwrap();
    assert_eq!(part2.epoch(), 0);
    assert_eq!(part2.steps(), 37);
    let mut src = LineSource::new(Cursor::new(tail), ds.dim());
    let r2 = part2.run(&mut src).unwrap();

    assert!(r2.completed);
    assert_eq!(r2.rows_ingested, 63, "this run only saw the tail");
    assert_eq!(
        r2.rows_stepped, full.rows_stepped,
        "total steps survive the resume"
    );
    assert_eq!(
        bits(&r2.model.w),
        bits(&full.model.w),
        "killed-and-resumed weights must be the uninterrupted weights"
    );
    assert_eq!(
        r2.model.objective.to_bits(),
        full.model.objective.to_bits()
    );
    assert_eq!(
        weights_crc32(&r2.model.w),
        weights_crc32(&full.model.w)
    );
    // The snapshot sequence kept ascending across the resume: the
    // pointer's seq is the last of `snapshots_published` monotonic
    // publishes (part 1's EOF snapshot was seq 0).
    let ptr = ModelPointer::load(&snap.join(POINTER_NAME)).unwrap();
    assert_eq!(ptr.seq + 1, r2.snapshots_published);
    assert!(r2.snapshots_published >= 2);
    for d in [&snap_full, &ckpt_full, &snap, &ckpt] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn conservative_update_is_sandwiched_by_truth_and_plain_updates() {
    // Property: for every observed item, true count ≤ conservative
    // estimate ≤ plain estimate — conservative update only tightens the
    // classic Count-Min overestimate, never undercounts. The plain
    // sketch here is the textbook oracle (`observe_plain`).
    check("count-min conservative sandwich", 24, |rng| {
        let depth = 2 + rng.gen_range(3) as usize;
        let width = 8 + rng.gen_range(56) as usize;
        let mut conservative = CountMin::new(depth, width);
        let mut plain = CountMin::new(depth, width);
        let mut truth: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
        let events = 50 + rng.gen_range(400);
        let universe = 1 + rng.gen_range(96);
        for _ in 0..events {
            let item = rng.gen_range(universe);
            conservative.observe(item);
            plain.observe_plain(item);
            *truth.entry(item).or_insert(0) += 1;
        }
        for (&item, &count) in &truth {
            let c = conservative.estimate(item);
            let p = plain.estimate(item);
            assert!(
                c >= count,
                "conservative undercounts item {item}: {c} < true {count} \
                 (depth {depth}, width {width})"
            );
            assert!(
                c <= p,
                "conservative exceeds plain for item {item}: {c} > {p} \
                 (depth {depth}, width {width})"
            );
        }
    });
}
