//! Multi-scheme integration: the `FeatureMap` redesign's acceptance
//! criteria.
//!
//! * scheme=bbit through the unified pipeline/store/trainer is
//!   bit-identical to the legacy b-bit path (rows, store bytes framing,
//!   trained weights);
//! * `bbit_vw` ≡ VW applied to the Theorem-2 expansion (paper §7), as a
//!   property over random shapes;
//! * store round-trips are bit-identical per scheme (gzip on/off), the
//!   version-1 header path still opens, and unknown scheme bytes are
//!   rejected as `InvalidData`;
//! * dense schemes run end-to-end: pipeline → store → out-of-core
//!   training, bit-identical to in-memory when shuffling is off, plus the
//!   CLI `train --scheme …` smoke.

use std::path::PathBuf;

use bbml::coordinator::pipeline::{
    hash_dataset, sketch_dataset, sketch_dataset_to_store, PipelineOptions,
};
use bbml::coordinator::stream_train::{
    train_epochs_sketch, train_stream, StreamAlgo, StreamTrainOptions,
};
use bbml::coordinator::trainer::{evaluate_sketch, train_sketch, Backend};
use bbml::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::bbit::pack_lowest_bits;
use bbml::hashing::expand_signature;
use bbml::hashing::feature_map::{BbitVwMap, FeatureMap, FeatureMapSpec, Scheme};
use bbml::hashing::sketch::SketchRow;
use bbml::proptest_mini::{check, gen};
use bbml::store::SigShardStore;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bbml_ischemes_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn corpus_cfg(n: usize) -> SynthConfig {
    SynthConfig {
        n_docs: n,
        dim: 1 << 20,
        vocab: 5_000,
        topic_size: 100,
        mean_len: 50,
        topic_mix: 0.5,
        ..Default::default()
    }
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn bbit_scheme_is_bit_identical_to_legacy_path() {
    // Acceptance: with scheme=bbit the unified pipeline emits the exact
    // words/labels of the historical hash_dataset, and training over the
    // unified entry point yields bit-identical weights.
    let ds = generate_corpus(&corpus_cfg(300));
    let opt = PipelineOptions {
        threads: 4,
        chunk: 17,
        queue: 2,
    };
    let (legacy, _) = hash_dataset(&ds, 24, 8, 7, &opt);
    let map = FeatureMapSpec::new(Scheme::Bbit, ds.dim(), 24, 8, 7).build();
    let (unified, stats) = sketch_dataset(&ds, map.as_ref(), &opt);
    let packed = unified.as_bbit().expect("bbit scheme emits packed rows");
    assert_eq!(packed.words(), legacy.words(), "rows must be bit-identical");
    assert_eq!(packed.labels(), legacy.labels());
    assert_eq!(stats.output_bytes, legacy.packed_bytes());

    let old = bbml::coordinator::trainer::train_signatures(
        &legacy,
        Backend::SvmDcd,
        1.0,
        3,
        None,
        None,
    )
    .unwrap();
    let new = train_sketch(&unified, Backend::SvmDcd, 1.0, 3, None, None).unwrap();
    assert_eq!(
        f32_bits(&old.model.w),
        f32_bits(&new.model.w),
        "trainer weights must be bit-identical"
    );
}

#[test]
fn bbit_store_keeps_version1_framing() {
    // Acceptance: spilling scheme=bbit writes version-1 shard files with
    // reserved-zero scheme/dtype bytes and a manifest without a scheme
    // line — byte-compatible with every pre-v2 store.
    let ds = generate_corpus(&corpus_cfg(120));
    let opt = PipelineOptions {
        threads: 2,
        chunk: 50,
        queue: 2,
    };
    let dir = tmp_dir("v1frame");
    let map = FeatureMapSpec::new(Scheme::Bbit, ds.dim(), 16, 4, 5).build();
    sketch_dataset_to_store(&ds, map.as_ref(), Scheme::Bbit, &opt, &dir, false).unwrap();
    let shard0 = std::fs::read(dir.join("shard-00000.bbs")).unwrap();
    assert_eq!(
        u32::from_le_bytes(shard0[8..12].try_into().unwrap()),
        1,
        "bbit shards stay version 1"
    );
    assert_eq!(shard0[52], 0, "scheme byte reserved-zero");
    assert_eq!(shard0[53], 0, "dtype byte reserved-zero");
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    assert!(manifest.contains("version = 1"), "{manifest}");
    assert!(!manifest.contains("scheme"), "{manifest}");
    let store = SigShardStore::open(&dir).unwrap();
    assert_eq!(store.scheme(), Scheme::Bbit);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dense_store_roundtrip_bit_identical_per_scheme() {
    // Satellite: write→read must be bit-identical for every dense scheme,
    // gzip on and off, ragged final shards included.
    let ds = generate_corpus(&corpus_cfg(130));
    for (scheme, gzip) in [
        (Scheme::Vw, false),
        (Scheme::Vw, true),
        (Scheme::ProjSparse, false),
        (Scheme::BbitVw, true),
    ] {
        let opt = PipelineOptions {
            threads: 4,
            chunk: 23, // 130 = 5·23 + 15: ragged tail
            queue: 2,
        };
        let map = FeatureMapSpec::new(scheme, ds.dim(), 16, 4, 11).build();
        let (mem, _) = sketch_dataset(&ds, map.as_ref(), &opt);
        let dir = tmp_dir(&format!("densert_{}_{gzip}", scheme.name()));
        let (summary, _) =
            sketch_dataset_to_store(&ds, map.as_ref(), scheme, &opt, &dir, gzip).unwrap();
        assert_eq!(summary.n_rows, 130);
        let store = SigShardStore::open(&dir).unwrap();
        assert_eq!(store.scheme(), scheme);
        assert_eq!(store.gzip(), gzip);
        let mut back_vals = Vec::new();
        let mut back_labels = Vec::new();
        for s in 0..store.n_shards() {
            let shard = store.read_shard(s).unwrap();
            let d = shard.as_dense().expect("dense store yields dense shards");
            back_vals.extend_from_slice(d.values());
            back_labels.extend_from_slice(d.labels());
        }
        let mem_d = mem.as_dense().unwrap();
        assert_eq!(
            f32_bits(&back_vals),
            f32_bits(mem_d.values()),
            "{scheme} gzip={gzip}: values must be bit-identical"
        );
        assert_eq!(f32_bits(&back_labels), f32_bits(mem_d.labels()));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn unknown_scheme_byte_is_rejected() {
    // Satellite: a v2 shard whose scheme byte is from a future writer
    // must fail as InvalidData — at the shard level and the store level.
    let ds = generate_corpus(&corpus_cfg(40));
    let opt = PipelineOptions {
        threads: 1,
        chunk: 40,
        queue: 2,
    };
    let dir = tmp_dir("unknown");
    let map = FeatureMapSpec::new(Scheme::Vw, ds.dim(), 8, 0, 3).build();
    sketch_dataset_to_store(&ds, map.as_ref(), Scheme::Vw, &opt, &dir, false).unwrap();
    let victim = dir.join("shard-00000.bbs");
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[52] = 200; // no such scheme
    std::fs::write(&victim, &bytes).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    let err = store.read_shard(0).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("unknown scheme"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_bbit_vw_equals_vw_of_expansion() {
    // Satellite property test (paper §7): for random shapes and random
    // documents, the fused bbit_vw encoder equals VW applied to
    // expand_signature of the truncated signature, value for value
    // (s = 1 signs sum to exact small integers in both f32 and f64).
    check("bbit_vw == vw ∘ expand", 25, |rng| {
        let dim = 1u64 << 20;
        let sig_k = 1 + (rng.next_u64() % 64) as usize;
        let b = 1 + (rng.next_u64() % 8) as u32;
        let buckets = 1 + (rng.next_u64() % 128) as usize;
        let seed = rng.next_u64();
        let map = BbitVwMap::new(dim, sig_k, b, buckets, seed);
        let set = gen::sparse_set(rng, dim, 1, 100);
        let mut scratch = SketchRow::new(&map.layout());
        map.encode_into(&set, scratch.row_mut());

        let full = map.minwise().signature(&set);
        let expanded = expand_signature(&pack_lowest_bits(&full, b), b);
        let want: Vec<f32> = map
            .vw()
            .hash_binary(&expanded)
            .iter()
            .map(|&v| v as f32)
            .collect();
        assert_eq!(
            f32_bits(scratch.dense()),
            f32_bits(&want),
            "sig_k={sig_k} b={b} buckets={buckets}"
        );
    });
}

#[test]
fn dense_streaming_training_is_bit_identical_to_in_memory() {
    // The out-of-core contract now holds per scheme: with shuffling off,
    // training from a dense shard stream produces the exact same model as
    // training over the resident sketch — weights AND objective bits.
    let ds = generate_corpus(&corpus_cfg(260));
    let opt = PipelineOptions {
        threads: 4,
        chunk: 31, // ragged: 260 = 8·31 + 12
        queue: 2,
    };
    let map = FeatureMapSpec::new(Scheme::Vw, ds.dim(), 64, 0, 9).build();
    let (mem, _) = sketch_dataset(&ds, map.as_ref(), &opt);
    let dir = tmp_dir("dense_equiv");
    sketch_dataset_to_store(&ds, map.as_ref(), Scheme::Vw, &opt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    assert_eq!(store.train_dim(), 64);

    for algo in [StreamAlgo::Pegasos, StreamAlgo::LogRegSgd] {
        let topt = StreamTrainOptions {
            algo,
            c: 1.0,
            epochs: 3,
            seed: 21,
            shuffle: false,
            row_shuffle: false,
            prefetch: 3,
            average: true,
        };
        let streamed = train_stream(&store, &topt).unwrap();
        let resident = train_epochs_sketch(&mem, &topt);
        assert_eq!(
            f32_bits(&streamed.model.w),
            f32_bits(&resident.w),
            "{algo:?}: dense streamed weights must be bit-identical"
        );
        assert_eq!(
            streamed.model.objective.to_bits(),
            resident.objective.to_bits(),
            "{algo:?}: objective must be bit-identical"
        );
        assert!(
            streamed.peak_resident_rows < store.n_rows(),
            "the full dense matrix must never be resident"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn every_scheme_trains_end_to_end_at_matched_storage() {
    // The headline experiment in miniature: all five registry schemes,
    // equal storage, through pipeline + trainer; accuracies recorded and
    // sane. (The full curve is benches/bench_schemes.rs.)
    let ds = generate_corpus(&corpus_cfg(360));
    let (train, test) = ds.train_test_split(0.25, 5);
    let opt = PipelineOptions::default();
    let (k, b) = (128usize, 8u32); // 1024 bits/example, dense k = 32
    for scheme in Scheme::ALL {
        let spec = match scheme {
            Scheme::Bbit | Scheme::BbitVw => FeatureMapSpec::new(scheme, ds.dim(), k, b, 11),
            _ => FeatureMapSpec::new(scheme, ds.dim(), (k * b as usize) / 32, 0, 11),
        };
        let map = spec.build();
        assert_eq!(
            map.layout().storage_bits_per_example(),
            k * b as usize,
            "{scheme}: matched storage"
        );
        let (sk_tr, _) = sketch_dataset(&train, map.as_ref(), &opt);
        let (sk_te, _) = sketch_dataset(&test, map.as_ref(), &opt);
        let out = train_sketch(&sk_tr, Backend::SvmDcd, 1.0, 3, None, None).unwrap();
        let (acc, _) = evaluate_sketch(&out.model, &sk_te);
        assert!(acc > 0.65, "{scheme}: test acc {acc} at 1024 bits");
    }
}

#[test]
fn prop_store_roundtrip_random_dense_shapes() {
    // Random (scheme, k, chunk, threads, gzip, n): the dense store path
    // must never bend a bit.
    let case = std::sync::atomic::AtomicUsize::new(0);
    check("dense store roundtrip", 6, |rng| {
        let schemes = [Scheme::Vw, Scheme::ProjSparse, Scheme::BbitVw];
        let scheme = schemes[(rng.next_u64() % 3) as usize];
        let k = 1 + rng.gen_range(24) as usize;
        let chunk = 1 + rng.gen_range(40) as usize;
        let threads = 1 + rng.gen_range(4) as usize;
        let gzip = rng.gen_range(2) == 1;
        let n = 1 + rng.gen_range(80) as usize;
        let dim = 1u64 << 16;
        let mut ds = SparseBinaryDataset::new(dim);
        for i in 0..n {
            let set = gen::sparse_set(rng, dim, 1, 30);
            ds.push(
                SparseBinaryVec::from_indices(set),
                if i % 2 == 0 { 1.0 } else { -1.0 },
            );
        }
        let opt = PipelineOptions {
            threads,
            chunk,
            queue: 2,
        };
        let map = FeatureMapSpec::new(scheme, dim, k, 4, 13).build();
        let (mem, _) = sketch_dataset(&ds, map.as_ref(), &opt);
        let id = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = tmp_dir(&format!("prop_dense_{id}"));
        let (summary, _) =
            sketch_dataset_to_store(&ds, map.as_ref(), scheme, &opt, &dir, gzip).unwrap();
        assert_eq!(summary.n_shards, n.div_ceil(chunk));
        let store = SigShardStore::open(&dir).unwrap();
        let mut vals = Vec::new();
        for s in 0..store.n_shards() {
            let shard = store.read_shard(s).unwrap();
            vals.extend_from_slice(shard.as_dense().unwrap().values());
        }
        assert_eq!(
            f32_bits(&vals),
            f32_bits(mem.as_dense().unwrap().values()),
            "{scheme} k={k} chunk={chunk} n={n}"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}
