//! Property-based invariants across the whole library (proptest_mini).

use bbml::data::shingle::Shingler;
use bbml::data::sparse::SparseBinaryVec;
use bbml::hashing::bbit::{pack_lowest_bits, BbitSignatureMatrix};
use bbml::hashing::expand::{expand_signature, expanded_dot};
use bbml::hashing::minwise::MinwiseHasher;
use bbml::hashing::perm::{Permutation, Permuter};
use bbml::hashing::vw::VwHasher;
use bbml::proptest_mini::{check, gen};

#[test]
fn prop_resemblance_is_a_bounded_symmetric_similarity() {
    check("resemblance bounds/symmetry", 100, |rng| {
        let a = SparseBinaryVec::from_indices(gen::sparse_set(rng, 10_000, 1, 100));
        let b = SparseBinaryVec::from_indices(gen::sparse_set(rng, 10_000, 1, 100));
        let r_ab = a.resemblance(&b);
        let r_ba = b.resemblance(&a);
        assert!((0.0..=1.0).contains(&r_ab));
        assert_eq!(r_ab, r_ba);
        assert_eq!(a.resemblance(&a), 1.0);
    });
}

#[test]
fn prop_simulated_permutations_are_bijections() {
    check("permutation bijectivity", 20, |rng| {
        let d = 2 + rng.gen_range(3000);
        let p = Permutation::new(d, rng.next_u64(), rng.gen_range(16));
        let mut seen = vec![false; d as usize];
        for x in 0..d {
            let y = p.apply(x);
            assert!(y < d, "image out of range");
            assert!(!seen[y as usize], "collision at {y}");
            seen[y as usize] = true;
        }
    });
}

#[test]
fn prop_signature_of_subset_shares_minima() {
    // If S2 ⊆ S1 then min π(S1) ≤ min π(S2) pointwise, and equal whenever
    // the overall min lands inside S2.
    check("subset minima", 50, |rng| {
        let d = 1 << 16;
        let s1 = gen::sparse_set(rng, d, 20, 100);
        let take = 1 + rng.gen_range(s1.len() as u64 / 2) as usize;
        let s2: Vec<u64> = s1[..take].to_vec();
        let h = MinwiseHasher::new(d, 32, rng.next_u64());
        let sig1 = h.signature(&s1);
        let sig2 = h.signature(&s2);
        for (a, b) in sig1.iter().zip(&sig2) {
            assert!(a <= b, "subset min must dominate");
        }
    });
}

#[test]
fn prop_batched_signature_equals_scalar_reference() {
    // The PR-2 tentpole invariant: the one-pass k-lane engine
    // (`signature_batch_into`) must be bit-identical to the per-permutation
    // scalar oracle (`signature_scalar_into`) across the full grid — lane
    // counts around and beyond the 4-lane group width (incl. k = 200, far
    // past the unroll), ragged set lengths that are no multiple of the
    // element block or the ×4 element unroll, and the empty-set sentinel.
    check("batched == scalar signatures", 25, |rng| {
        for &k in &[1usize, 4, 7, 64, 200] {
            let d = 2 + rng.gen_range(1 << 20);
            let h = MinwiseHasher::new(d, k, rng.next_u64());
            // Lengths 1..=70 cover 31/32/33-style block boundaries; allow
            // duplicate elements (min is idempotent, but the engine must
            // not care either way).
            let len = 1 + rng.gen_range(70) as usize;
            let set: Vec<u64> = (0..len).map(|_| rng.gen_range(d)).collect();
            let mut batch = Vec::new();
            let mut scalar = Vec::new();
            h.signature_batch_into(&set, &mut batch);
            h.signature_scalar_into(&set, &mut scalar);
            assert_eq!(batch, scalar, "k={k} d={d} len={len}");
            assert!(batch.iter().all(|&z| z < d), "k={k}: image out of range");
            // Empty-set sentinel: all-d from both paths.
            h.signature_batch_into(&[], &mut batch);
            h.signature_scalar_into(&[], &mut scalar);
            assert_eq!(batch, scalar, "k={k} empty-set");
            assert!(batch.iter().all(|&z| z == d) && batch.len() == k);
        }
    });
}

#[test]
fn prop_packing_roundtrip_and_expansion_count() {
    check("pack/expand invariants", 100, |rng| {
        let k = 1 + rng.gen_range(64) as usize;
        let b = 1 + rng.gen_range(16) as u32;
        let full: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
        let packed = pack_lowest_bits(&full, b);
        // Truncation honours the mask.
        for (&z, &p) in full.iter().zip(&packed) {
            assert_eq!((z & ((1 << b) - 1)) as u16, p);
        }
        // Round-trip through the bit-packed matrix.
        let mut m = BbitSignatureMatrix::new(k, b);
        m.push_row(&packed, 1.0);
        assert_eq!(m.row(0), packed);
        // Theorem-2 expansion: exactly k ones, self-dot = k.
        let e = expand_signature(&packed, b);
        assert_eq!(e.len(), k);
        assert_eq!(expanded_dot(&packed, &packed), k);
        // Distinct blocks: index j lives in [j·2^b, (j+1)·2^b).
        for (j, &idx) in e.iter().enumerate() {
            let w = 1u64 << b;
            assert!(idx >= j as u64 * w && idx < (j as u64 + 1) * w);
        }
    });
}

#[test]
fn prop_fused_pack_equals_scalar_reference() {
    // The PR-6 tentpole invariant: the fused encode route (lanes → packed
    // words in one pass, no u16 detour) must be bit-identical to the legacy
    // reference composition `pack_lowest_bits` ∘ signature ∘ `push_row`,
    // for every supported width b — including widths that straddle word
    // boundaries (b ∤ 64) — over ragged k (no multiple of the lane group or
    // the 64/b packing period) and the empty-set sentinel.
    check("fused pack == scalar reference", 20, |rng| {
        let d = 2 + rng.gen_range(1 << 20);
        for &b in &[1u32, 2, 3, 4, 7, 8, 12, 16] {
            let k = 1 + rng.gen_range(150) as usize;
            let h = MinwiseHasher::new(d, k, rng.next_u64());
            let sets: [Vec<u64>; 3] = [
                gen::sparse_set(rng, d, 1, 60),
                Vec::new(), // empty-set sentinel row (all-d lanes)
                gen::sparse_set(rng, d, 1, 60),
            ];

            // Reference: legacy three-buffer route, one push_row per set.
            let mut want = BbitSignatureMatrix::new(k, b);
            for set in &sets {
                want.push_row(&pack_lowest_bits(&h.signature(set), b), 0.0);
            }

            // Fused route 1: signature_packed_into + push_packed_row.
            let mut got = BbitSignatureMatrix::new(k, b);
            let mut lanes = Vec::new();
            let mut words = Vec::new();
            for set in &sets {
                h.signature_packed_into(set, b, &mut lanes, &mut words);
                got.push_packed_row(&words, 0.0);
            }
            // Fused route 2: push_row_from_lanes (matrix-side packer).
            let mut got2 = BbitSignatureMatrix::new(k, b);
            for set in &sets {
                h.signature_batch_into(set, &mut lanes);
                got2.push_row_from_lanes(&lanes, 0.0);
            }

            assert_eq!(got.words(), want.words(), "packed_into b={b} k={k}");
            assert_eq!(got2.words(), want.words(), "from_lanes b={b} k={k}");
            for i in 0..sets.len() {
                assert_eq!(got.row(i), want.row(i), "row {i} b={b} k={k}");
            }
        }
    });
}

#[test]
fn prop_fold_min_lane_widths_agree() {
    // The 8-wide production engine and the 4-wide engine are two lane-width
    // instantiations of the same fold; both must match the per-permutation
    // scalar oracle on ragged k around and across both group widths.
    check("fold-min lane widths agree", 20, |rng| {
        let d = 2 + rng.gen_range(1 << 20);
        for &k in &[1usize, 3, 4, 5, 7, 8, 9, 11, 15, 16, 23, 40] {
            let seed = rng.next_u64();
            let h = MinwiseHasher::new(d, k, seed);
            let set = gen::sparse_set(rng, d, 1, 80);
            let (mut x8, mut x4, mut scalar) = (Vec::new(), Vec::new(), Vec::new());
            h.signature_batch_into(&set, &mut x8);
            h.signature_scalar_into(&set, &mut scalar);
            x4.resize(k, u64::MAX);
            bbml::hashing::PermutationBank::new(d, seed, k).fold_min_into_x4(&set, &mut x4);
            assert_eq!(x8, scalar, "x8 vs scalar k={k}");
            assert_eq!(x4, scalar, "x4 vs scalar k={k}");
        }
    });
}

#[test]
fn prop_swar_match_count_equals_scalar_reference() {
    // The tentpole invariant: the word-parallel kernel must agree with the
    // scalar get_bits reference for every supported width, including
    // widths that straddle word boundaries (b ∤ 64) and row shapes where
    // k·b is not a multiple of 64.
    check("swar == scalar match_count", 60, |rng| {
        for &b in &[1u32, 2, 3, 4, 7, 8, 12, 16] {
            let k = 1 + rng.gen_range(300) as usize;
            let mask = (1u32 << b) - 1;
            let r1: Vec<u16> = (0..k).map(|_| (rng.next_u32() & mask) as u16).collect();
            // Share ~half the positions with r1 so counts are nontrivial.
            let r2: Vec<u16> = r1
                .iter()
                .map(|&v| {
                    if rng.next_u64() & 1 == 0 {
                        v
                    } else {
                        (rng.next_u32() & mask) as u16
                    }
                })
                .collect();
            let mut m = BbitSignatureMatrix::new(k, b);
            m.push_row(&r1, 1.0);
            m.push_row(&r2, -1.0);
            let expect = r1.iter().zip(&r2).filter(|(a, c)| a == c).count();
            assert_eq!(m.match_count(0, 1), expect, "b={b} k={k}");
            for (i, j) in [(0, 1), (1, 0), (0, 0), (1, 1)] {
                assert_eq!(
                    m.match_count(i, j),
                    m.match_count_scalar(i, j),
                    "b={b} k={k} ({i},{j})"
                );
            }
        }
    });
}

#[test]
fn prop_block_tiles_match_pairwise_and_parallel() {
    check("match_count tiles == pairwise", 20, |rng| {
        let b = [1u32, 2, 4, 8, 16][rng.gen_range(5) as usize];
        let k = 1 + rng.gen_range(200) as usize;
        let n = 3 + rng.gen_range(40) as usize;
        let mask = (1u32 << b) - 1;
        let mut m = BbitSignatureMatrix::new(k, b);
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| (rng.next_u32() & mask) as u16).collect();
            m.push_row(&row, 1.0);
        }
        let rows: Vec<usize> = (0..n).collect();
        let tile = m.match_count_block(&rows, &rows);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    tile[i * n + j] as usize,
                    m.match_count(i, j),
                    "b={b} k={k} ({i},{j})"
                );
            }
        }
        for threads in [2usize, 4, 7] {
            assert_eq!(
                m.match_count_block_par(&rows, &rows, threads),
                tile,
                "b={b} threads={threads}"
            );
        }
    });
}

#[test]
fn prop_zero_copy_merge_equals_row_pushes() {
    // Shards appended word-for-word (or placed out of order) must be
    // bit-identical to pushing the same rows one by one.
    check("zero-copy shard merge", 30, |rng| {
        let b = 1 + rng.gen_range(16) as u32;
        let k = 1 + rng.gen_range(50) as usize;
        let n = 2 + rng.gen_range(30) as usize;
        let mask = (1u32 << b) - 1;
        let rows: Vec<Vec<u16>> = (0..n)
            .map(|_| (0..k).map(|_| (rng.next_u32() & mask) as u16).collect())
            .collect();
        let mut want = BbitSignatureMatrix::new(k, b);
        for (i, r) in rows.iter().enumerate() {
            want.push_row(r, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        // Split into shards at a random boundary.
        let cut = 1 + rng.gen_range((n - 1) as u64) as usize;
        let mut s0 = BbitSignatureMatrix::new(k, b);
        for (i, r) in rows[..cut].iter().enumerate() {
            s0.push_row(r, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let mut s1 = BbitSignatureMatrix::new(k, b);
        for (i, r) in rows[cut..].iter().enumerate() {
            s1.push_row(r, if (cut + i) % 2 == 0 { 1.0 } else { -1.0 });
        }
        // Path 1: in-order append.
        let mut merged = BbitSignatureMatrix::new(k, b);
        merged.append(&s0);
        merged.append(&s1);
        // Path 2: out-of-order placement into a pre-sized target.
        let mut placed = BbitSignatureMatrix::with_rows(k, b, n);
        placed.copy_rows_from(&s1, cut);
        placed.copy_rows_from(&s0, 0);
        assert_eq!(merged.n(), n);
        for i in 0..n {
            assert_eq!(merged.row_words(i), want.row_words(i), "append row {i}");
            assert_eq!(placed.row_words(i), want.row_words(i), "placed row {i}");
            assert_eq!(merged.label(i), want.label(i));
            assert_eq!(placed.label(i), want.label(i));
        }
    });
}

#[test]
fn prop_match_count_triangle_consistency() {
    // match(i,j) + match(j,l) − k ≤ match(i,l) (equality-pattern overlap).
    check("match-count triangle", 50, |rng| {
        let k = 32;
        let b = 4;
        let mut m = BbitSignatureMatrix::new(k, b);
        for _ in 0..3 {
            let row: Vec<u16> = (0..k).map(|_| (rng.next_u32() & 15) as u16).collect();
            m.push_row(&row, 1.0);
        }
        let (ij, jl, il) = (m.match_count(0, 1), m.match_count(1, 2), m.match_count(0, 2));
        assert!(il + k >= ij + jl, "triangle violated: {ij}+{jl} vs {il}+{k}");
    });
}

#[test]
fn prop_vw_is_sparsity_preserving_and_linear() {
    check("vw sparsity + linearity", 50, |rng| {
        let set = gen::sparse_set(rng, 1 << 30, 10, 200);
        let k = 64 + rng.gen_range(1024) as usize;
        let h = VwHasher::new(k, rng.next_u64());
        let sparse = h.hash_binary_sparse(&set);
        assert!(sparse.len() <= set.len(), "sparsity preservation");
        // Linearity: hashing the union of disjoint halves = sum of hashes.
        let mid = set.len() / 2;
        let g_full = h.hash_binary(&set);
        let g_a = h.hash_binary(&set[..mid]);
        let g_b = h.hash_binary(&set[mid..]);
        for i in 0..k {
            assert!((g_full[i] - (g_a[i] + g_b[i])).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_shingles_bounded_and_deterministic() {
    check("shingling", 50, |rng| {
        let w = 1 + rng.gen_range(5) as usize;
        let dim = 100 + rng.gen_range(1 << 20);
        let s = Shingler::new(w, dim);
        let len = rng.gen_range(200) as usize;
        let ids: Vec<u64> = (0..len).map(|_| rng.gen_range(5_000)).collect();
        let v1 = s.shingle_token_ids(&ids);
        let v2 = s.shingle_token_ids(&ids);
        assert_eq!(v1, v2);
        assert!(v1.indices().iter().all(|&i| i < dim));
        if len >= w {
            assert!(v1.nnz() <= len - w + 1);
        }
    });
}

#[test]
fn prop_bbit_gram_matrices_are_positive_semidefinite() {
    // Theorem 2, verified numerically: the match-count Gram matrix of any
    // signature set has no negative eigenvalues (checked via Cholesky-with-
    // jitter on random instances).
    check("PSD Gram", 25, |rng| {
        let n = 4 + rng.gen_range(8) as usize;
        let k = 16;
        let b = 1 + rng.gen_range(8) as u32;
        let mut m = BbitSignatureMatrix::new(k, b);
        for _ in 0..n {
            let row: Vec<u16> = (0..k)
                .map(|_| (rng.next_u32() & ((1u32 << b) - 1)) as u16)
                .collect();
            m.push_row(&row, 1.0);
        }
        // Gram matrix G[i][j] = match/k.
        let mut g = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                g[i][j] = m.match_count(i, j) as f64 / k as f64;
            }
        }
        // Cholesky with tiny jitter must succeed for a PSD matrix.
        let jitter = 1e-9;
        let mut l = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = g[i][j];
                for t in 0..j {
                    sum -= l[i][t] * l[j][t];
                }
                if i == j {
                    let v = sum + jitter;
                    assert!(v > 0.0, "negative pivot {v} at {i} — not PSD");
                    l[i][i] = v.sqrt();
                } else {
                    l[i][j] = sum / l[j][j];
                }
            }
        }
    });
}
