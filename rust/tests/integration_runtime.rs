//! PJRT runtime integration: load real AOT artifacts, execute, and
//! cross-check numerics against the pure-rust implementations.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially, with a note) when `artifacts/manifest.txt` is absent so
//! `cargo test` works in a fresh checkout.

use bbml::coordinator::pipeline::{hash_dataset, PipelineOptions};
use bbml::coordinator::trainer::{evaluate, evaluate_pjrt, train_signatures, Backend};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::bbit::BbitSignatureMatrix;
use bbml::rng::Xoshiro256;
use bbml::runtime::Runtime;
use bbml::solvers::{BinaryFeatures, ExpandedView};

fn runtime() -> Option<Runtime> {
    let rt = Runtime::try_default();
    if rt.is_none() {
        eprintln!("skipping: no artifacts/ — run `make artifacts` first");
    }
    rt
}

fn random_sigs(n: usize, k: usize, b: u32, seed: u64) -> BbitSignatureMatrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut m = BbitSignatureMatrix::new(k, b);
    for i in 0..n {
        let row: Vec<u16> = (0..k)
            .map(|_| (rng.next_u32() & ((1u32 << b) - 1)) as u16)
            .collect();
        m.push_row(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
    }
    m
}

#[test]
fn pjrt_predict_matches_rust_scorer() {
    let Some(rt) = runtime() else { return };
    // Production shape: k=200, b=8 (the compiled artifact's contract).
    let sigs = random_sigs(300, 200, 8, 1); // non-multiple of 256: pads
    let mut rng = Xoshiro256::seed_from_u64(2);
    let w: Vec<f32> = (0..200 * 256).map(|_| rng.gen_f32() - 0.5).collect();
    let scores = rt.predict_scores(&sigs, &w).unwrap();
    assert_eq!(scores.len(), sigs.n());
    let view = ExpandedView::new(&sigs);
    for i in 0..sigs.n() {
        let expect = view.dot(i, &w);
        assert!(
            (scores[i] - expect).abs() < 1e-3 * (1.0 + expect.abs()),
            "row {i}: pjrt {} vs rust {}",
            scores[i],
            expect
        );
    }
}

#[test]
fn pjrt_match_count_matches_rust() {
    let Some(rt) = runtime() else { return };
    let a = random_sigs(130, 200, 8, 3);
    let b = random_sigs(140, 200, 8, 4);
    let a_rows: Vec<usize> = (0..a.n()).collect();
    let b_rows: Vec<usize> = (0..b.n()).collect();
    let k = rt.match_count(&a, &a_rows, &b, &b_rows).unwrap();
    assert_eq!(k.len(), a.n());
    assert_eq!(k[0].len(), b.n());
    let mut ra = vec![0u16; 200];
    let mut rb = vec![0u16; 200];
    for (i, &ia) in a_rows.iter().enumerate().step_by(17) {
        a.unpack_row_into(ia, &mut ra);
        for (j, &jb) in b_rows.iter().enumerate().step_by(13) {
            b.unpack_row_into(jb, &mut rb);
            let expect = ra.iter().zip(&rb).filter(|(x, y)| x == y).count() as f32;
            assert_eq!(k[i][j], expect, "({i},{j})");
        }
    }
}

#[test]
fn pjrt_training_learns_and_scorers_agree() {
    let Some(rt) = runtime() else { return };
    let cfg = SynthConfig {
        n_docs: 700,
        dim: 1 << 22,
        vocab: 10_000,
        mean_len: 80,
        topic_mix: 0.3,
        ..Default::default()
    };
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.25, 5);
    let opt = PipelineOptions::default();
    let (sig_tr, _) = hash_dataset(&train, 200, 8, 21, &opt);
    let (sig_te, _) = hash_dataset(&test, 200, 8, 21, &opt);

    let out =
        train_signatures(&sig_tr, Backend::PjrtLogReg, 1.0, 3, Some(&rt), None).unwrap();
    let (acc_rust, _) = evaluate(&out.model, &sig_te);
    let (acc_pjrt, _) = evaluate_pjrt(&out.model, &sig_te, &rt).unwrap();
    assert!(acc_rust > 0.85, "pjrt-trained model accuracy {acc_rust}");
    assert!(
        (acc_rust - acc_pjrt).abs() < 1e-9,
        "scorers disagree: rust {acc_rust} vs pjrt {acc_pjrt}"
    );
}

#[test]
fn pjrt_small_artifacts_run_too() {
    let Some(rt) = runtime() else { return };
    // The n=8/k=16/b=4 variants exist for fast tests.
    let sigs = random_sigs(8, 16, 4, 9);
    let w = vec![0.1f32; 16 * 16];
    let scores = rt.predict_scores(&sigs, &w).unwrap();
    // Every expanded row has exactly k ones ⇒ score = 0.1·16 = 1.6.
    for s in scores {
        assert!((s - 1.6).abs() < 1e-5, "{s}");
    }
    let out = rt
        .train_step(
            bbml::runtime::ArtifactKind::SvmStep,
            &sigs,
            &(0..8).collect::<Vec<_>>(),
            &w,
            1.0,
            0.01,
        )
        .unwrap();
    assert_eq!(out.w.len(), 256);
    assert!(out.loss.is_finite());
}
