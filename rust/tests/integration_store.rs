//! Shard-store integration: write→read bit-identity across the full
//! operating grid, bounded reader memory, and the out-of-core training
//! equivalence contract (streaming with shuffling off ≡ in-memory, bit for
//! bit).

use std::path::PathBuf;

use bbml::coordinator::pipeline::{
    hash_dataset, hash_dataset_to_store, PipelineOptions,
};
use bbml::coordinator::stream_train::{
    evaluate_stream, train_epochs_in_memory, train_stream, StreamAlgo, StreamTrainOptions,
};
use bbml::data::sparse::{SparseBinaryDataset, SparseBinaryVec};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::bbit::BbitSignatureMatrix;
use bbml::proptest_mini::{check, gen};
use bbml::store::SigShardStore;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bbml_istore_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn corpus_cfg(n: usize) -> SynthConfig {
    SynthConfig {
        n_docs: n,
        dim: 1 << 20,
        vocab: 5_000,
        topic_size: 100,
        mean_len: 50,
        topic_mix: 0.5,
        ..Default::default()
    }
}

/// Read a whole store back into one matrix (sequential shard order).
fn read_all(store: &SigShardStore) -> BbitSignatureMatrix {
    let mut all = BbitSignatureMatrix::new(store.k(), store.b());
    for s in 0..store.n_shards() {
        let shard = store.read_shard(s).unwrap();
        all.append(shard.as_bbit().expect("bbit store yields packed shards"));
    }
    all
}

#[test]
fn roundtrip_bit_identical_across_b_chunks_threads_gzip() {
    // Satellite: write→read must be bit-identical to the in-memory matrix
    // for every paper operating point b, with ragged final shards, odd
    // chunk sizes, any thread count, gzip on and off.
    let ds = generate_corpus(&corpus_cfg(300));
    for (b, chunk, threads, gzip) in [
        (1u32, 17usize, 4usize, false), // 300 = 17·17 + 11: ragged tail
        (2, 64, 1, true),
        (4, 23, 8, false), // 300 = 13·23 + 1: 1-row tail shard
        (8, 300, 2, true), // single shard
        (16, 7, 4, true),  // many tiny shards
    ] {
        let opt = PipelineOptions {
            threads,
            chunk,
            queue: 2,
        };
        let (mem, _) = hash_dataset(&ds, 24, b, 5, &opt);
        let dir = tmp_dir(&format!("rt_{b}_{chunk}_{threads}_{gzip}"));
        let (summary, _) = hash_dataset_to_store(&ds, 24, b, 5, &opt, &dir, gzip).unwrap();
        assert_eq!(summary.n_rows, 300);
        assert_eq!(summary.n_shards, 300usize.div_ceil(chunk));
        let store = SigShardStore::open(&dir).unwrap();
        assert_eq!(store.gzip(), gzip);
        let back = read_all(&store);
        assert_eq!(back.n(), mem.n(), "b={b} chunk={chunk}");
        assert_eq!(
            back.words(),
            mem.words(),
            "b={b} chunk={chunk} threads={threads} gzip={gzip}: words differ"
        );
        assert_eq!(back.labels(), mem.labels());
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn prop_roundtrip_on_random_shapes() {
    // Random (k, b, chunk, threads, gzip, n) — the store must never bend
    // a bit, including the non-SWAR widths b ∈ {3, 5, ...}.
    let case = std::sync::atomic::AtomicUsize::new(0);
    check("store roundtrip", 8, |rng| {
        let k = 1 + rng.gen_range(40) as usize;
        let b = 1 + rng.gen_range(16) as u32;
        let chunk = 1 + rng.gen_range(50) as usize;
        let threads = 1 + rng.gen_range(8) as usize;
        let gzip = rng.gen_range(2) == 1;
        let n = 1 + rng.gen_range(120) as usize;
        let dim = 1u64 << 16;
        let mut ds = SparseBinaryDataset::new(dim);
        for i in 0..n {
            let set = gen::sparse_set(rng, dim, 1, 40);
            ds.push(
                SparseBinaryVec::from_indices(set),
                if i % 2 == 0 { 1.0 } else { -1.0 },
            );
        }
        let opt = PipelineOptions {
            threads,
            chunk,
            queue: 2,
        };
        let (mem, _) = hash_dataset(&ds, k, b, 11, &opt);
        let id = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = tmp_dir(&format!("prop_{id}"));
        let (summary, _) =
            hash_dataset_to_store(&ds, k, b, 11, &opt, &dir, gzip).unwrap();
        assert_eq!(summary.n_shards, n.div_ceil(chunk));
        let store = SigShardStore::open(&dir).unwrap();
        let back = read_all(&store);
        assert_eq!(back.words(), mem.words(), "k={k} b={b} chunk={chunk} n={n}");
        assert_eq!(back.labels(), mem.labels());
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn streaming_training_is_bit_identical_to_in_memory() {
    // THE acceptance criterion: with shuffling off, training from the
    // shard stream produces the exact same model as training in memory —
    // same seed, same floating-point op sequence, bit-for-bit weights.
    let ds = generate_corpus(&corpus_cfg(400));
    let opt = PipelineOptions {
        threads: 4,
        chunk: 37, // ragged: 400 = 10·37 + 30
        queue: 2,
    };
    let (mem, _) = hash_dataset(&ds, 32, 4, 9, &opt);
    let dir = tmp_dir("equiv");
    hash_dataset_to_store(&ds, 32, 4, 9, &opt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();

    for algo in [StreamAlgo::Pegasos, StreamAlgo::LogRegSgd] {
        for average in [true, false] {
            let topt = StreamTrainOptions {
                algo,
                c: 1.0,
                epochs: 3,
                seed: 21,
                shuffle: false,
                row_shuffle: false,
                prefetch: 3,
                average,
            };
            let streamed = train_stream(&store, &topt).unwrap();
            let resident = train_epochs_in_memory(&mem, &topt);
            assert_eq!(
                streamed.model.w, resident.w,
                "{algo:?} average={average}: weights must be bit-identical"
            );
            assert_eq!(
                streamed.model.objective.to_bits(),
                resident.objective.to_bits(),
                "{algo:?} average={average}: objective must be bit-identical"
            );
            assert_eq!(streamed.rows_seen, 3 * 400);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reader_memory_stays_bounded() {
    // The out-of-core acceptance criterion, measured: the reader holds at
    // most queue · chunk rows at any instant (queue = prefetch clamped to
    // ≥ 3) — a fraction of the corpus — while training still sees every
    // row of every epoch.
    let ds = generate_corpus(&corpus_cfg(400));
    let (chunk, prefetch) = (16usize, 2usize);
    let opt = PipelineOptions {
        threads: 4,
        chunk,
        queue: 2,
    };
    let dir = tmp_dir("bounded");
    hash_dataset_to_store(&ds, 16, 4, 3, &opt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    assert_eq!(store.n_shards(), 25);
    let report = train_stream(
        &store,
        &StreamTrainOptions {
            epochs: 2,
            shuffle: true,
            prefetch,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.rows_seen, 2 * 400, "every row of every epoch visited");
    assert!(report.peak_resident_rows > 0);
    let ceiling = prefetch.max(3) * chunk;
    assert!(
        report.peak_resident_rows <= ceiling,
        "peak {} rows exceeds the queue·chunk = {ceiling} ceiling",
        report.peak_resident_rows
    );
    assert!(
        report.peak_resident_rows < store.n_rows(),
        "the full matrix must never be resident"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shuffled_streaming_is_deterministic_and_learns() {
    let ds = generate_corpus(&corpus_cfg(300));
    let opt = PipelineOptions {
        threads: 4,
        chunk: 32,
        queue: 2,
    };
    let dir = tmp_dir("shuffle");
    hash_dataset_to_store(&ds, 64, 8, 11, &opt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    let topt = StreamTrainOptions {
        algo: StreamAlgo::Pegasos,
        epochs: 100,
        seed: 5,
        shuffle: true,
        ..Default::default()
    };
    let a = train_stream(&store, &topt).unwrap();
    let b = train_stream(&store, &topt).unwrap();
    assert_eq!(a.model.w, b.model.w, "seeded shard shuffling is deterministic");
    // A different seed permutes shards differently and lands elsewhere.
    let c = train_stream(
        &store,
        &StreamTrainOptions {
            seed: 6,
            ..topt.clone()
        },
    )
    .unwrap();
    assert_ne!(a.model.w, c.model.w, "seed must drive the shard permutation");
    let (acc, rows) = evaluate_stream(&a.model, &store, 4).unwrap();
    assert_eq!(rows, 300);
    assert!(acc > 0.8, "streamed training should learn: acc {acc}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_hash_store_then_train_stream_writes_parseable_report() {
    // The CI smoke path, exercised in-process: hash-to-disk, train from
    // disk, and the JSON report exists with the fields CI asserts on.
    let base = tmp_dir("cli");
    let store_dir = base.join("sig");
    let out_dir = base.join("results");
    let strs = |v: &[&str]| -> Vec<String> { v.iter().map(|s| s.to_string()).collect() };
    bbml::cli::run_with(&strs(&[
        "hash-store",
        "--k",
        "16",
        "--b",
        "4",
        "--chunk",
        "48",
        "--store",
        store_dir.to_str().unwrap(),
        "n_docs=200",
        "dim=1048576",
        "vocab=2000",
        "mean_len=40",
    ]))
    .unwrap();
    bbml::cli::run_with(&strs(&[
        "train-stream",
        "--backend",
        "pegasos",
        "--epochs",
        "2",
        "--store",
        store_dir.to_str().unwrap(),
        &format!("out_dir={}", out_dir.to_str().unwrap()),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(out_dir.join("stream_report.json")).unwrap();
    for key in ["\"backend\"", "\"rows\"", "\"acc\"", "\"peak_resident_rows\""] {
        assert!(text.contains(key), "report missing {key}: {text}");
    }
    assert!(text.contains("\"rows\": 200"), "{text}");
    std::fs::remove_dir_all(&base).ok();
}
