//! bbml-lint self-tests: per-rule fixtures (a known-bad source that must
//! produce the exact finding, and a known-good twin that must pass), the
//! suppression contract (a reasoned allow silences, a reason-less allow is
//! itself a finding), and the keystone check — the lint runs clean on this
//! repo's real `src/` tree, which is what keeps every fixture honest.
//!
//! Fixtures are inline string literals: the scanner blanks string contents
//! before rule matching, so the banned tokens quoted *inside this file*
//! never leak into a lint of the test tree itself.

use std::path::Path;

use bbml::analysis::rules::{
    R1_BUFFER_CONTRACT, R2_HOT_PATH_ALLOC, R3_NO_UNWRAP, R4_FORMAT_DRIFT, R5_ORACLE_RETENTION,
    R6_HOT_PATH_TRANSITIVE, R7_LOCK_DISCIPLINE, R8_ATOMIC_ORDERING, R9_FLOAT_DETERMINISM,
};
use bbml::analysis::{lint_sources, lint_sources_scoped, lint_tree, LintReport};

fn src(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs
        .iter()
        .map(|(p, t)| (p.to_string(), t.to_string()))
        .collect()
}

fn lint_lib(pairs: &[(&str, &str)]) -> LintReport {
    lint_sources(&src(pairs), &[])
}

/// Assert the report contains exactly the expected `(rule, line)` pairs,
/// in any multiplicity order, and nothing else.
fn assert_findings(rep: &LintReport, expected: &[(&str, usize)]) {
    let mut got: Vec<(&str, usize)> = rep.findings.iter().map(|f| (f.rule, f.line)).collect();
    let mut want = expected.to_vec();
    got.sort_unstable();
    want.sort_unstable();
    assert_eq!(got, want, "findings:\n{}", rep.render_text());
}

// ---------------------------------------------------------------- R1 ----

#[test]
fn r1_flags_into_without_mut_dest_bad_return_and_buffer_steal() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn pack_into(v: &[u64]) -> Vec<u64> {\n\
         \x20   v.to_vec()\n\
         }\n\
         pub fn steal_into(dst: &mut Vec<u64>, src: &mut Vec<u64>) {\n\
         \x20   *dst = std::mem::take(src);\n\
         }\n",
    )]);
    assert_findings(
        &rep,
        &[
            (R1_BUFFER_CONTRACT, 1), // no &mut destination
            (R1_BUFFER_CONTRACT, 1), // returns Vec<u64>
            (R1_BUFFER_CONTRACT, 5), // mem::take inside an _into body
        ],
    );
    assert!(rep.findings[0].message.contains("pack_into"));
}

#[test]
fn r1_accepts_the_contractual_shapes() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn fill_into(out: &mut [u64], x: u64) {\n\
         \x20   out[0] = x;\n\
         }\n\
         pub fn encode_into(set: &[u32], row: RowMut<'_>) -> io::Result<()> {\n\
         \x20   Ok(())\n\
         }\n",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R2 ----

#[test]
fn r2_flags_alloc_in_annotated_hot_path_only() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "// bbml-lint: hot-path\n\
         pub fn hot(out: &mut Vec<u64>) {\n\
         \x20   let tmp: Vec<u64> = (0..4).collect();\n\
         \x20   out.extend(tmp.clone());\n\
         }\n\
         pub fn cold(out: &mut Vec<u64>) {\n\
         \x20   let tmp: Vec<u64> = (0..4).collect();\n\
         \x20   out.extend(tmp);\n\
         }\n",
    )]);
    assert_findings(
        &rep,
        &[(R2_HOT_PATH_ALLOC, 3), (R2_HOT_PATH_ALLOC, 4)],
    );
    assert!(rep.findings[0].message.contains("hot"));
}

#[test]
fn r2_accepts_amortized_buffer_reuse() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "// bbml-lint: hot-path\n\
         pub fn hot(out: &mut Vec<u64>, row: &[u64]) {\n\
         \x20   out.clear();\n\
         \x20   out.reserve(row.len());\n\
         \x20   out.extend_from_slice(row);\n\
         }\n",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R3 ----

#[test]
fn r3_flags_unwrap_expect_panic_in_library_code() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n\
         \x20   x.unwrap()\n\
         }\n\
         pub fn g(x: Option<u32>) -> u32 {\n\
         \x20   x.expect(\"present\")\n\
         }\n\
         pub fn h() {\n\
         \x20   panic!(\"boom\");\n\
         }\n",
    )]);
    assert_findings(
        &rep,
        &[(R3_NO_UNWRAP, 2), (R3_NO_UNWRAP, 5), (R3_NO_UNWRAP, 8)],
    );
}

#[test]
fn r3_skips_cfg_test_regions_debug_assert_and_strings() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> bool {\n\
         \x20   debug_assert!(x.map(|v| v > 0).unwrap_or(true));\n\
         \x20   // a comment saying .unwrap() is not a call\n\
         \x20   let s = \".unwrap()\";\n\
         \x20   !s.is_empty()\n\
         }\n\
         #[cfg(test)]\n\
         mod tests {\n\
         \x20   #[test]\n\
         \x20   fn t() {\n\
         \x20       Some(1u32).unwrap();\n\
         \x20   }\n\
         }\n",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R4 ----

/// A minimal store/mod.rs + store/format.rs pair that satisfies every R4
/// check: contiguous doc tables with terminators, header-length constants,
/// the magic literal, the documented version, and matching encode ranges.
const R4_GOOD_DOCS: &str = "\
//! # Shard file layout (version 1)
//!
//! ```text
//! offset  size  field
//! ------  ----  -----
//!      0     8  magic            b\"BBSHARD\\0\"
//!      8     4  version          u32
//!     12     4  n_rows           u32
//!     16     …  payload
//! ```
//!
//! # Framed blob formats (CKPT)
//!
//! ```text
//!      0     4  magic            b\"BBCK\" (alias BBCKPT)
//!      4     4  payload_crc32    u32
//!      8     …  payload
//! ```
";

const R4_GOOD_FORMAT: &str = "\
pub const MAGIC: &[u8; 8] = b\"BBSHARD\\0\";
pub const VERSION: u32 = 1;
pub const HEADER_LEN: usize = 16;
pub const FRAMED_HEADER_LEN: usize = 8;
impl ShardHeader {
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[0..8].copy_from_slice(MAGIC);
        out[8..12].copy_from_slice(&self.version.to_le_bytes());
        out[12..16].copy_from_slice(&self.n_rows.to_le_bytes());
        out
    }
}
";

#[test]
fn r4_accepts_agreeing_docs_and_codec() {
    let rep = lint_lib(&[
        ("src/store/mod.rs", R4_GOOD_DOCS),
        ("src/store/format.rs", R4_GOOD_FORMAT),
    ]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

#[test]
fn r4_flags_header_len_and_encode_range_drift() {
    // Same docs, but the codec disagrees: HEADER_LEN says 24 while the
    // documented payload starts at 16, and n_rows is written as 8 bytes
    // where the table documents 4.
    let drifted = R4_GOOD_FORMAT
        .replace("HEADER_LEN: usize = 16", "HEADER_LEN: usize = 24")
        .replace("out[12..16].copy_from_slice(&self.n_rows", "out[12..20].copy_from_slice(&self.n_rows");
    let rep = lint_lib(&[
        ("src/store/mod.rs", R4_GOOD_DOCS),
        ("src/store/format.rs", &drifted),
    ]);
    let rules: Vec<&str> = rep.findings.iter().map(|f| f.rule).collect();
    assert_eq!(
        rules,
        vec![R4_FORMAT_DRIFT, R4_FORMAT_DRIFT],
        "{}",
        rep.render_text()
    );
    assert!(rep.findings.iter().any(|f| f.message.contains("HEADER_LEN")));
    assert!(rep.findings.iter().any(|f| f.message.contains("n_rows")));
}

#[test]
fn r4_flags_noncontiguous_doc_table() {
    let gapped = R4_GOOD_DOCS.replace("//!     12     4  n_rows", "//!     13     4  n_rows");
    let rep = lint_lib(&[
        ("src/store/mod.rs", &gapped),
        ("src/store/format.rs", R4_GOOD_FORMAT),
    ]);
    assert!(
        rep.findings
            .iter()
            .any(|f| f.rule == R4_FORMAT_DRIFT && f.message.contains("n_rows")),
        "{}",
        rep.render_text()
    );
}

#[test]
fn r4_only_runs_on_the_store_pair() {
    // The same drifted codec under a different path is out of R4's scope.
    let rep = lint_lib(&[("src/other.rs", R4_GOOD_FORMAT)]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

/// The serve wire-frame table appended to the store docs, mirroring the
/// real one: BBSERVE magic, contiguous rows, payload terminator at 32.
const R4_SERVE_DOCS_TABLE: &str = "\
//! # Serve wire frames (version 1)
//!
//! ```text
//!      0     8  magic            b\"BBSERVE\\0\"
//!      8     4  version          u32
//!     12     4  frame_type       u32
//!     16     8  payload_len      u64
//!     24     4  payload_crc32    u32
//!     28     4  reserved         zero
//!     32     …  payload
//! ```
";

const R4_SERVE_PROTO: &str = "\
pub const FRAME_MAGIC: [u8; 8] = *b\"BBSERVE\\0\";
pub const FRAME_VERSION: u32 = 1;
pub const FRAME_HEADER_LEN: usize = 32;
impl FrameHeader {
    pub fn encode(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut out = [0u8; FRAME_HEADER_LEN];
        out[0..8].copy_from_slice(&FRAME_MAGIC);
        out[8..12].copy_from_slice(&self.version.to_le_bytes());
        out[12..16].copy_from_slice(&self.frame_type.to_le_bytes());
        out[16..24].copy_from_slice(&self.payload_len.to_le_bytes());
        out[24..28].copy_from_slice(&self.payload_crc32.to_le_bytes());
        out
    }
}
";

fn serve_docs() -> String {
    format!("{R4_GOOD_DOCS}{R4_SERVE_DOCS_TABLE}")
}

#[test]
fn r4_accepts_agreeing_serve_protocol_and_table() {
    let docs = serve_docs();
    let rep = lint_lib(&[
        ("src/store/mod.rs", &docs),
        ("src/store/format.rs", R4_GOOD_FORMAT),
        ("src/serve/protocol.rs", R4_SERVE_PROTO),
    ]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

#[test]
fn r4_flags_serve_header_len_version_and_encode_drift() {
    // Three independent drifts: FRAME_HEADER_LEN disagrees with the
    // documented payload offset, FRAME_VERSION disagrees with the table
    // heading, and frame_type is written wider than documented.
    let docs = serve_docs();
    let drifted = R4_SERVE_PROTO
        .replace("FRAME_HEADER_LEN: usize = 32", "FRAME_HEADER_LEN: usize = 40")
        .replace("FRAME_VERSION: u32 = 1", "FRAME_VERSION: u32 = 2")
        .replace(
            "out[12..16].copy_from_slice(&self.frame_type",
            "out[12..18].copy_from_slice(&self.frame_type",
        );
    let rep = lint_lib(&[
        ("src/store/mod.rs", &docs),
        ("src/store/format.rs", R4_GOOD_FORMAT),
        ("src/serve/protocol.rs", &drifted),
    ]);
    let rules: Vec<&str> = rep.findings.iter().map(|f| f.rule).collect();
    assert_eq!(
        rules,
        vec![R4_FORMAT_DRIFT, R4_FORMAT_DRIFT, R4_FORMAT_DRIFT],
        "{}",
        rep.render_text()
    );
    for needle in ["FRAME_HEADER_LEN", "FRAME_VERSION", "frame_type"] {
        assert!(
            rep.findings.iter().any(|f| f.message.contains(needle)),
            "missing {needle}:\n{}",
            rep.render_text()
        );
    }
}

#[test]
fn r4_flags_serve_protocol_without_doc_table_and_vice_versa() {
    // A protocol with no documented table is drift…
    let rep = lint_lib(&[
        ("src/store/mod.rs", R4_GOOD_DOCS),
        ("src/store/format.rs", R4_GOOD_FORMAT),
        ("src/serve/protocol.rs", R4_SERVE_PROTO),
    ]);
    assert_eq!(rep.findings.len(), 1, "{}", rep.render_text());
    assert!(rep.findings[0].message.contains("BBSERVE"));

    // …and so is a documented table with no protocol behind it.
    let docs = serve_docs();
    let rep = lint_lib(&[
        ("src/store/mod.rs", &docs),
        ("src/store/format.rs", R4_GOOD_FORMAT),
    ]);
    assert_eq!(rep.findings.len(), 1, "{}", rep.render_text());
    assert!(rep.findings[0].message.contains("serve/protocol.rs"));
}

#[test]
fn r4_flags_overlapping_rows_from_a_merged_table() {
    // A second layout table that fails to restart at offset 0 gets parsed
    // into the previous one: its rows claim already-assigned bytes and it
    // contributes a second payload terminator. Both are drift.
    let merged = format!(
        "{R4_GOOD_DOCS}\
         //!      6     4  tail             u32\n\
         //!     10     …  payload\n"
    );
    let rep = lint_lib(&[
        ("src/store/mod.rs", &merged),
        ("src/store/format.rs", R4_GOOD_FORMAT),
    ]);
    assert_findings(&rep, &[(R4_FORMAT_DRIFT, 19), (R4_FORMAT_DRIFT, 20)]);
    assert!(
        rep.findings.iter().any(|f| f.message.contains("overlap")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings
            .iter()
            .any(|f| f.message.contains("second payload terminator")),
        "{}",
        rep.render_text()
    );
}

// ---------------------------------------------------------------- R5 ----

#[test]
fn r5_flags_unreferenced_oracles_by_doc_phrase_and_annotation() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "/// The bit-identity oracle the fused path must match.\n\
         pub fn slow_ref(xs: &[u64]) -> u64 {\n\
         \x20   xs.iter().sum()\n\
         }\n\
         // bbml-lint: oracle\n\
         pub fn scalar_ref(xs: &[u64]) -> u64 {\n\
         \x20   xs.iter().fold(0, |a, b| a ^ b)\n\
         }\n",
    )]);
    assert_findings(
        &rep,
        &[(R5_ORACLE_RETENTION, 2), (R5_ORACLE_RETENTION, 6)],
    );
}

#[test]
fn r5_satisfied_by_tests_dir_or_cfg_test_references() {
    let lib = "\
/// The bit-identity oracle the fused path must match.
pub fn slow_ref(xs: &[u64]) -> u64 {
    xs.iter().sum()
}
// bbml-lint: oracle
pub fn scalar_ref(xs: &[u64]) -> u64 {
    xs.iter().fold(0, |a, b| a ^ b)
}
#[cfg(test)]
mod tests {
    #[test]
    fn pins_scalar() {
        assert_eq!(super::scalar_ref(&[1, 2]), 3);
    }
}
";
    let tests = "\
#[test]
fn pins_slow() {
    assert_eq!(bbml::slow_ref(&[1, 2]), 3);
}
";
    let rep = lint_sources(
        &src(&[("src/fix.rs", lib)]),
        &src(&[("tests/integration_fix.rs", tests)]),
    );
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R6 ----

#[test]
fn r6_flags_transitive_alloc_chain_and_unresolved_callee() {
    // `hot` itself is clean under R2; the allocation hides one call down
    // (`helper -> grow`), and `dup()` is ambiguous crate-wide so the call
    // graph refuses to resolve it.
    let fix = "\
// bbml-lint: hot-path
pub fn hot(out: &mut Vec<u64>) {
    helper(out);
    dup();
}
pub fn helper(out: &mut Vec<u64>) {
    grow(out);
}
pub fn grow(out: &mut Vec<u64>) {
    let tmp: Vec<u64> = (0..4).collect();
    out.extend(tmp);
}
";
    let dup = "pub fn dup() {}\n";
    let rep = lint_lib(&[("src/fix.rs", fix), ("src/a.rs", dup), ("src/b.rs", dup)]);
    assert_findings(
        &rep,
        &[(R6_HOT_PATH_TRANSITIVE, 3), (R6_HOT_PATH_TRANSITIVE, 4)],
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("helper -> grow")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("ambiguous")),
        "{}",
        rep.render_text()
    );
}

#[test]
fn r6_accepts_alloc_free_chains_and_justified_amortized_allocs() {
    // A reasoned allow on the allocating line stops the taint: a justified
    // amortized allocation must not poison every transitive caller.
    let rep = lint_lib(&[(
        "src/fix.rs",
        "\
// bbml-lint: hot-path
pub fn hot(out: &mut Vec<u64>, row: &[u64]) {
    helper(out, row);
    amortized(out);
}
pub fn helper(out: &mut Vec<u64>, row: &[u64]) {
    out.extend_from_slice(row);
}
pub fn amortized(out: &mut Vec<u64>) {
    if out.capacity() == 0 {
        // bbml-lint: allow(hot-path-alloc) reason: one-time seed built on
        // first call; every later call reuses the buffer's capacity
        let seed: Vec<u64> = (0..4).collect();
        out.extend(seed);
    }
}
",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

#[test]
fn r6_resolves_chains_across_scopes_but_reports_on_lib_only() {
    // The call graph spans every scope: a lib hot path reaching an
    // allocating bench helper is a finding, anchored at the lib call site.
    let lib = "\
// bbml-lint: hot-path
pub fn hot(out: &mut Vec<u64>) {
    bench_helper(out);
}
";
    let bench = "\
pub fn bench_helper(out: &mut Vec<u64>) {
    let tmp: Vec<u64> = (0..4).collect();
    out.extend(tmp);
}
";
    let rep = lint_sources_scoped(
        &src(&[("src/fix.rs", lib)]),
        &src(&[("benches/b.rs", bench)]),
        &[],
    );
    assert_findings(&rep, &[(R6_HOT_PATH_TRANSITIVE, 3)]);
    assert_eq!(rep.findings[0].file, "src/fix.rs");
    assert!(rep.findings[0].message.contains("bench_helper"));
}

// ---------------------------------------------------------------- R7 ----

#[test]
fn r7_flags_blocking_double_acquire_order_violation_and_call_chains() {
    let fix = "\
use std::sync::Mutex;
pub struct S {
    pub rx: Mutex<u64>,
    pub inner: Mutex<u64>,
    pub cache: Mutex<u64>,
}
impl S {
    pub fn bad_io(&self) -> u64 {
        let g = self.inner.lock();
        std::thread::sleep(std::time::Duration::from_millis(1));
        1
    }
    pub fn bad_double(&self) -> u64 {
        let a = self.cache.lock();
        let b = self.cache.lock();
        2
    }
    pub fn bad_order(&self) -> u64 {
        let c = self.cache.lock();
        let i = self.inner.lock();
        3
    }
    pub fn bad_call(&self) -> u64 {
        let g = self.rx.lock();
        slow()
    }
}
pub fn slow() -> u64 {
    std::thread::sleep(std::time::Duration::from_millis(1));
    4
}
";
    let rep = lint_lib(&[("src/fix.rs", fix)]);
    assert_findings(
        &rep,
        &[
            (R7_LOCK_DISCIPLINE, 10), // thread::sleep under `inner`
            (R7_LOCK_DISCIPLINE, 15), // double acquisition of `cache`
            (R7_LOCK_DISCIPLINE, 20), // `inner` acquired under `cache`
            (R7_LOCK_DISCIPLINE, 25), // call to blocking `slow` under `rx`
        ],
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("self-deadlock")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("LOCK_ORDER")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings
            .iter()
            .any(|f| f.message.contains("`slow` (which blocks)")),
        "{}",
        rep.render_text()
    );
}

#[test]
fn r7_accepts_dropped_guards_and_declared_order() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "\
use std::sync::Mutex;
pub struct S {
    pub inner: Mutex<u64>,
    pub cache: Mutex<u64>,
}
impl S {
    pub fn ok_drop_before_io(&self) -> u64 {
        let g = self.inner.lock();
        drop(g);
        std::thread::sleep(std::time::Duration::from_millis(1));
        1
    }
    pub fn ok_ordered(&self) -> u64 {
        let i = self.inner.lock();
        let c = self.cache.lock();
        2
    }
}
",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R8 ----

#[test]
fn r8_flags_strong_gauges_weak_handoffs_and_unclassified_receivers() {
    let fix = "\
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
pub struct S {
    hits: AtomicU64,
    stop: AtomicBool,
}
impl S {
    pub fn bad_gauge(&self) {
        self.hits.fetch_add(1, Ordering::SeqCst);
    }
    pub fn bad_handoff(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
    pub fn bad_unknown(&self, flag: &AtomicBool) {
        let alias = flag;
        alias.store(true, Ordering::Release);
    }
}
";
    let rep = lint_lib(&[("src/fix.rs", fix)]);
    assert_findings(
        &rep,
        &[
            (R8_ATOMIC_ORDERING, 8),  // SeqCst on a gauge
            (R8_ATOMIC_ORDERING, 11), // Relaxed load of a handoff
            (R8_ATOMIC_ORDERING, 15), // unclassified `alias`
        ],
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("must be Relaxed")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("expected Acquire")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings
            .iter()
            .any(|f| f.message.contains("no classified declaration")),
        "{}",
        rep.render_text()
    );
}

#[test]
fn r8_accepts_classified_orderings_and_gauge_override() {
    // `seen` is an AtomicBool forced to gauge by annotation; `stop` keeps
    // the handoff default and pairs Acquire/Release/AcqRel correctly
    // (CAS: AcqRel success, Acquire failure).
    let rep = lint_lib(&[(
        "src/fix.rs",
        "\
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
pub struct S {
    hits: AtomicU64,
    stop: AtomicBool,
    // bbml-lint: atomic(gauge)
    seen: AtomicBool,
}
impl S {
    pub fn ok(&self) -> bool {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.seen.store(true, Ordering::Relaxed);
        if self.stop.load(Ordering::Acquire) {
            return true;
        }
        self.stop.store(true, Ordering::Release);
        let _ = self
            .stop
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire);
        self.stop.swap(true, Ordering::AcqRel)
    }
}
",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// ---------------------------------------------------------------- R9 ----

#[test]
fn r9_flags_hash_iteration_partial_cmp_and_worker_reductions() {
    // All three sites live in `SgdCore` methods, i.e. on the bit-identity
    // reachability roots.
    let fix = "\
use std::collections::HashMap;
pub struct SgdCore {
    pub w: Vec<f32>,
}
impl SgdCore {
    pub fn step(&mut self, grads: &HashMap<u32, f32>) -> f32 {
        let mut total = 0.0f32;
        for (_k, g) in grads.iter() {
            total += 0.5 * *g;
        }
        total
    }
    pub fn rank(&self, xs: &mut Vec<f32>) {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    }
    pub fn par_sum(&self) -> f32 {
        let handle = std::thread::spawn(move || {
            let mut local = 0.0f32;
            local += 1.0;
            local
        });
        0.0
    }
}
";
    let rep = lint_lib(&[("src/fix.rs", fix)]);
    assert_findings(
        &rep,
        &[
            (R9_FLOAT_DETERMINISM, 8),  // grads.iter() into `total +=`
            (R9_FLOAT_DETERMINISM, 14), // partial_cmp sort
            (R9_FLOAT_DETERMINISM, 19), // `local +=` inside spawn
        ],
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("hash-ordered")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("total_cmp")),
        "{}",
        rep.render_text()
    );
    assert!(
        rep.findings.iter().any(|f| f.message.contains("worker thread")),
        "{}",
        rep.render_text()
    );
}

#[test]
fn r9_accepts_sorted_views_and_total_cmp() {
    // The sanctioned shapes: a BTreeMap (deterministic iteration order)
    // and total_cmp for float sorts.
    let rep = lint_lib(&[(
        "src/fix.rs",
        "\
use std::collections::BTreeMap;
pub struct SgdCore {
    pub w: Vec<f32>,
}
impl SgdCore {
    pub fn step(&mut self, grads: &BTreeMap<u32, f32>) -> f32 {
        let mut total = 0.0f32;
        for (_k, g) in grads.iter() {
            total += 0.5 * *g;
        }
        total
    }
    pub fn rank(&self, xs: &mut Vec<f32>) {
        xs.sort_by(|a, b| a.total_cmp(b));
    }
}
",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
}

// --------------------------------------------------- baseline & SARIF ----

#[test]
fn baseline_roundtrip_subtracts_lint_findings_and_survives_line_drift() {
    let bad = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let rep = lint_lib(&[("src/fix.rs", bad)]);
    assert_eq!(rep.findings.len(), 1);
    let baseline = rep.to_json();

    // Same finding, accepted by the baseline → clean exit.
    let mut rep = lint_lib(&[("src/fix.rs", bad)]);
    rep.apply_baseline(&baseline).expect("baseline parses");
    assert!(rep.is_clean(), "{}", rep.render_text());
    assert_eq!(rep.baselined, 1);

    // The finding moved two lines down (unrelated edit): still baselined —
    // matching is (file, rule, message), not line.
    let drifted = format!("// a\n// b\n{bad}");
    let mut rep = lint_lib(&[("src/fix.rs", &drifted)]);
    rep.apply_baseline(&baseline).expect("baseline parses");
    assert!(rep.is_clean(), "{}", rep.render_text());

    // A second instance of the same violation is NEW and kept.
    let doubled = "pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {\n    x.unwrap()\n        + y.unwrap()\n}\n";
    let mut rep = lint_lib(&[("src/fix.rs", doubled)]);
    rep.apply_baseline(&baseline).expect("baseline parses");
    assert_eq!(rep.baselined, 1);
    assert_eq!(rep.findings.len(), 1, "{}", rep.render_text());
}

#[test]
fn sarif_document_carries_lint_findings_with_locations() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )]);
    let sarif = rep.to_sarif();
    assert!(sarif.contains("\"version\": \"2.1.0\""));
    assert!(sarif.contains("\"ruleId\": \"no-unwrap\""));
    assert!(sarif.contains("\"uri\": \"src/fix.rs\""));
    assert!(sarif.contains("\"startLine\": 2"));
    // The driver advertises the full rule catalog, including the v2 rules.
    for id in [
        "hot-path-transitive",
        "lock-discipline",
        "atomic-ordering",
        "float-determinism",
    ] {
        assert!(sarif.contains(&format!("\"id\": \"{id}\"")), "missing {id}");
    }
}

#[test]
fn committed_baseline_is_empty_and_parses() {
    // CI lints with `--baseline results/LINT_baseline.json`; the committed
    // document must parse and accept nothing — the tree is clean, so any
    // finding is new by definition.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("results/LINT_baseline.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing {}: {e}", path.display()));
    let mut rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )]);
    rep.apply_baseline(&text).expect("committed baseline parses");
    assert_eq!(rep.baselined, 0, "the committed baseline must stay empty");
    assert_eq!(rep.findings.len(), 1);
}

// ------------------------------------------------------- suppressions ----

#[test]
fn reasoned_allow_suppresses_and_is_counted() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n\
         \x20   // bbml-lint: allow(no-unwrap) reason: contract check on\n\
         \x20   // programmer error, not on input\n\
         \x20   x.unwrap()\n\
         }\n",
    )]);
    assert!(rep.is_clean(), "{}", rep.render_text());
    assert_eq!(rep.suppressed, 1);
}

#[test]
fn reasonless_allow_does_not_suppress_and_is_itself_reported() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n\
         \x20   // bbml-lint: allow(no-unwrap)\n\
         \x20   x.unwrap()\n\
         }\n",
    )]);
    assert_eq!(rep.suppressed, 0);
    assert_findings(&rep, &[(R3_NO_UNWRAP, 3), ("lint-directive", 2)]);
    assert!(rep.findings.iter().any(|f| f.message.contains("no reason")));
}

#[test]
fn allow_of_unknown_rule_is_reported() {
    let rep = lint_lib(&[(
        "src/fix.rs",
        "// bbml-lint: allow(no-such-rule) reason: because\n\
         pub fn f() {}\n",
    )]);
    assert_findings(&rep, &[("lint-directive", 1)]);
    assert!(rep.findings[0].message.contains("unknown rule"));
}

#[test]
fn allow_covers_only_its_target_line() {
    // The directive anchors to the next code line; a second violation two
    // lines down stays reported.
    let rep = lint_lib(&[(
        "src/fix.rs",
        "pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {\n\
         \x20   // bbml-lint: allow(no-unwrap) reason: checked above\n\
         \x20   let a = x.unwrap();\n\
         \x20   a + y.unwrap()\n\
         }\n",
    )]);
    assert_eq!(rep.suppressed, 1);
    assert_findings(&rep, &[(R3_NO_UNWRAP, 4)]);
}

// ----------------------------------------------------- the real tree ----

#[test]
fn lint_runs_clean_on_this_repo() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let rep = lint_tree(root).expect("lint_tree walks the crate");
    assert!(
        rep.is_clean(),
        "bbml-lint found contract violations in the tree:\n{}",
        rep.render_text()
    );
    assert!(
        rep.files_scanned > 50,
        "expected the full src tree, scanned only {} files",
        rep.files_scanned
    );
    // The tree carries justified suppressions (layout-guard panics, poison
    // recovery notes); the count proves the allow machinery ran.
    assert!(rep.suppressed > 0);
}
