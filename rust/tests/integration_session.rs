//! Model-lifecycle integration: checkpoint/resume bit-identity (property
//! over algo × shuffle × row-shuffle × averaging), legacy-wrapper
//! equivalence, partitioned training + weight-averaging merge, store
//! merge, and end-to-end predict from a saved `ModelArtifact`.

use std::path::{Path, PathBuf};

use bbml::coordinator::pipeline::{
    hash_dataset, hash_dataset_to_store, sketch_dataset, sketch_dataset_to_store,
    PipelineOptions,
};
use bbml::coordinator::session::{CheckpointConfig, SessionPlan, TrainSession};
use bbml::coordinator::stream_train::{
    evaluate_stream, train_epochs_in_memory, train_stream, StreamAlgo, StreamTrainOptions,
};
use bbml::coordinator::{merge_weighted, predict_artifact, trainer};
use bbml::data::synth::{generate_corpus, SynthConfig};
use bbml::hashing::feature_map::{FeatureMapSpec, Scheme};
use bbml::proptest_mini::check;
use bbml::store::{merge_stores, ModelArtifact, SigShardStore};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bbml_isess_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn corpus_cfg(n: usize) -> SynthConfig {
    SynthConfig {
        n_docs: n,
        dim: 1 << 20,
        vocab: 5_000,
        topic_size: 100,
        mean_len: 50,
        topic_mix: 0.5,
        ..Default::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// List a checkpoint dir's named checkpoints in write order.
fn checkpoint_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("ckpt-"))
        })
        .collect();
    files.sort();
    files
}

#[test]
fn resume_from_any_checkpoint_is_bit_identical() {
    // THE acceptance criterion: a run interrupted at ANY checkpoint and
    // resumed produces bit-identical weights and objective to the
    // uninterrupted run — across both algorithms, shuffle on/off,
    // row-shuffle on/off, averaging on/off.
    let ds = generate_corpus(&corpus_cfg(130));
    let popt = PipelineOptions {
        threads: 4,
        chunk: 13, // 130 = 10 shards
        queue: 2,
    };
    let store_dir = tmp_dir("prop_store");
    hash_dataset_to_store(&ds, 16, 4, 9, &popt, &store_dir, false).unwrap();
    let store = SigShardStore::open(&store_dir).unwrap();

    let case = std::sync::atomic::AtomicUsize::new(0);
    check("ckpt resume bit-identity", 8, |rng| {
        let opt = StreamTrainOptions {
            algo: if rng.gen_range(2) == 0 {
                StreamAlgo::Pegasos
            } else {
                StreamAlgo::LogRegSgd
            },
            c: 1.0,
            epochs: 2 + rng.gen_range(2) as usize,
            seed: rng.next_u64(),
            shuffle: rng.gen_range(2) == 1,
            row_shuffle: rng.gen_range(2) == 1,
            prefetch: 3,
            average: rng.gen_range(2) == 1,
        };
        let id = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let ckpt_dir = tmp_dir(&format!("prop_ckpt_{id}"));
        let ckpt = CheckpointConfig::new(&ckpt_dir).every(1);

        // Uninterrupted run (checkpointing must not perturb training).
        let full = TrainSession::new(&store, opt.clone())
            .unwrap()
            .run(&store, Some(&ckpt))
            .unwrap();
        // The wrapper is the same machinery, bit for bit.
        let plain = train_stream(&store, &opt).unwrap();
        assert_eq!(bits(&full.model.w), bits(&plain.model.w), "{opt:?}");
        assert_eq!(
            full.model.objective.to_bits(),
            plain.model.objective.to_bits()
        );

        // "Kill" at a random checkpoint, resume, run to completion.
        let files = checkpoint_files(&ckpt_dir);
        assert!(
            files.len() >= opt.epochs * store.n_shards(),
            "every shard and epoch boundary checkpointed: {} files",
            files.len()
        );
        let pick = &files[rng.gen_range(files.len() as u64) as usize];
        let resumed = TrainSession::resume(pick, &store)
            .unwrap()
            .run(&store, None)
            .unwrap();
        assert_eq!(
            bits(&resumed.model.w),
            bits(&full.model.w),
            "resume from {} must be bit-identical ({opt:?})",
            pick.display()
        );
        assert_eq!(
            resumed.model.objective.to_bits(),
            full.model.objective.to_bits(),
            "objective must be bit-identical"
        );
        assert_eq!(resumed.rows_seen, full.rows_seen, "rows_seen survives resume");
        std::fs::remove_dir_all(&ckpt_dir).ok();
    });
    std::fs::remove_dir_all(&store_dir).ok();
}

#[test]
fn row_shuffle_changes_visits_but_keeps_the_single_shard_fixed_point() {
    let ds = generate_corpus(&corpus_cfg(150));
    let popt = PipelineOptions {
        threads: 4,
        chunk: 25,
        queue: 2,
    };
    let (mem, _) = hash_dataset(&ds, 16, 4, 7, &popt);
    let dir = tmp_dir("rowshuf");
    hash_dataset_to_store(&ds, 16, 4, 7, &popt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    let base = StreamTrainOptions {
        epochs: 3,
        seed: 11,
        shuffle: true,
        prefetch: 3,
        ..Default::default()
    };
    // Row shuffling changes the model (it is a real behavior change)…
    let with = train_stream(
        &store,
        &StreamTrainOptions {
            row_shuffle: true,
            ..base.clone()
        },
    )
    .unwrap();
    let without = train_stream(
        &store,
        &StreamTrainOptions {
            row_shuffle: false,
            ..base.clone()
        },
    )
    .unwrap();
    assert_ne!(
        bits(&with.model.w),
        bits(&without.model.w),
        "row shuffling must actually permute multi-row shards"
    );
    // …is deterministic…
    let again = train_stream(
        &store,
        &StreamTrainOptions {
            row_shuffle: true,
            ..base.clone()
        },
    )
    .unwrap();
    assert_eq!(bits(&with.model.w), bits(&again.model.w));
    // …is inert when shard shuffling is off (bit-identical to the
    // pre-session behavior, which the in-memory oracle still encodes)…
    let off_a = train_stream(
        &store,
        &StreamTrainOptions {
            shuffle: false,
            row_shuffle: true,
            ..base.clone()
        },
    )
    .unwrap();
    let off_b = train_stream(
        &store,
        &StreamTrainOptions {
            shuffle: false,
            row_shuffle: false,
            ..base.clone()
        },
    )
    .unwrap();
    assert_eq!(bits(&off_a.model.w), bits(&off_b.model.w));
    let oracle = train_epochs_in_memory(
        &mem,
        &StreamTrainOptions {
            shuffle: false,
            row_shuffle: true,
            ..base.clone()
        },
    );
    assert_eq!(bits(&off_a.model.w), bits(&oracle.w));
    std::fs::remove_dir_all(&dir).ok();

    // Single-shard store: shuffle AND row-shuffle on, still the in-memory
    // fixed point — the row permutation seed (epoch, seq=0) matches.
    let dir1 = tmp_dir("rowshuf_single");
    let popt1 = PipelineOptions {
        threads: 2,
        chunk: 4096, // one shard
        queue: 2,
    };
    let (mem1, _) = hash_dataset(&ds, 16, 4, 7, &popt1);
    hash_dataset_to_store(&ds, 16, 4, 7, &popt1, &dir1, false).unwrap();
    let store1 = SigShardStore::open(&dir1).unwrap();
    assert_eq!(store1.n_shards(), 1);
    let streamed = train_stream(&store1, &base).unwrap();
    let resident = train_epochs_in_memory(&mem1, &base);
    assert_eq!(
        bits(&streamed.model.w),
        bits(&resident.w),
        "single-shard store stays the fixed point with both shuffles on"
    );
    assert_eq!(
        streamed.model.objective.to_bits(),
        resident.objective.to_bits()
    );
    std::fs::remove_dir_all(&dir1).ok();
}

#[test]
fn partitioned_workers_merge_into_a_working_model() {
    let ds = generate_corpus(&corpus_cfg(300));
    let popt = PipelineOptions {
        threads: 4,
        chunk: 30, // 10 shards
        queue: 2,
    };
    let dir = tmp_dir("plan");
    hash_dataset_to_store(&ds, 64, 8, 11, &popt, &dir, false).unwrap();
    let store = SigShardStore::open(&dir).unwrap();
    let plan = SessionPlan::for_store(&store);
    let ranges = plan.partition(3);
    assert_eq!(ranges.len(), 3);
    assert_eq!(ranges.first().unwrap().start, 0);
    assert_eq!(ranges.last().unwrap().end, store.n_shards());

    let opt = StreamTrainOptions {
        epochs: 80,
        seed: 5,
        ..Default::default()
    };
    let mut parts = Vec::new();
    let mut rows_covered = 0usize;
    for r in ranges {
        let sess = TrainSession::new_range(&store, opt.clone(), r.clone()).unwrap();
        let report = sess.run(&store, None).unwrap();
        rows_covered += report.rows_seen / opt.epochs;
        parts.push((report.model, report.rows_seen / opt.epochs));
    }
    assert_eq!(rows_covered, store.n_rows(), "ranges tile every row");
    let merged = merge_weighted(&parts);
    assert_eq!(merged.w.len(), store.train_dim());
    assert!(merged.w.iter().all(|x| x.is_finite()));
    let (acc, rows) = evaluate_stream(&merged, &store, 3).unwrap();
    assert_eq!(rows, store.n_rows());
    assert!(
        acc > 0.75,
        "weight-averaged partitioned training should learn: acc {acc}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn merged_stores_train_like_the_concatenation() {
    // Hash two halves of one corpus into separate stores (as independent
    // nodes would), merge, and train — the merged store must behave as the
    // single-store hash of the same rows, bit for bit.
    let ds = generate_corpus(&corpus_cfg(200));
    let (first, second) = ds.train_test_split(0.5, 3);
    let popt = PipelineOptions {
        threads: 2,
        chunk: 16,
        queue: 2,
    };
    let (d1, d2, dm, dw) = (
        tmp_dir("m_src1"),
        tmp_dir("m_src2"),
        tmp_dir("m_dst"),
        tmp_dir("m_whole"),
    );
    hash_dataset_to_store(&first, 16, 4, 9, &popt, &d1, false).unwrap();
    hash_dataset_to_store(&second, 16, 4, 9, &popt, &d2, false).unwrap();
    let merged = SigShardStore::merge(&[d1.as_path(), d2.as_path()], &dm).unwrap();
    assert_eq!(merged.n_rows(), 200);

    // The same rows hashed as one dataset: same hasher seed ⇒ the merged
    // store must train bit-identically to it (shuffle off).
    let mut both = first.clone();
    for (row, label) in second.iter() {
        both.push(bbml::data::sparse::SparseBinaryVec::from_indices(row.to_vec()), label);
    }
    hash_dataset_to_store(&both, 16, 4, 9, &popt, &dw, false).unwrap();
    let whole = SigShardStore::open(&dw).unwrap();
    let opt = StreamTrainOptions {
        epochs: 3,
        shuffle: false,
        ..Default::default()
    };
    let a = train_stream(&merged, &opt).unwrap();
    let b = train_stream(&whole, &opt).unwrap();
    assert_eq!(
        bits(&a.model.w),
        bits(&b.model.w),
        "merge must be pure concatenation, bit for bit"
    );

    // Rejections: a store of a different scheme cannot merge with bbit.
    let spec = FeatureMapSpec::new(Scheme::Vw, first.dim(), 16, 0, 9);
    let map = spec.build();
    let dv = tmp_dir("m_vw");
    sketch_dataset_to_store(&first, map.as_ref(), Scheme::Vw, &popt, &dv, false).unwrap();
    let err = merge_stores(&[d1.as_path(), dv.as_path()], &tmp_dir("m_rej")).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    for d in [&d1, &d2, &dm, &dw, &dv] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn predict_end_to_end_from_saved_artifact() {
    // The model lifecycle, end to end: train → save → load → predict on
    // raw libsvm rows, for a packed scheme (bbit) and a dense one (vw).
    let ds = generate_corpus(&corpus_cfg(400));
    let (train, test) = ds.train_test_split(0.25, 5);
    let popt = PipelineOptions::default();
    for scheme in [Scheme::Bbit, Scheme::Vw] {
        // bbit: 64 perms x 8 bits; vw: 256 buckets (the width the dense
        // trainer tests already vouch for).
        let k = if scheme == Scheme::Vw { 256 } else { 64 };
        let spec = FeatureMapSpec::new(scheme, ds.dim(), k, 8, 11);
        let map = spec.build();
        let (sk_tr, _) = sketch_dataset(&train, map.as_ref(), &popt);
        let (sk_te, _) = sketch_dataset(&test, map.as_ref(), &popt);
        let out =
            trainer::train_sketch(&sk_tr, trainer::Backend::SvmDcd, 1.0, 3, None, None).unwrap();
        let (acc_direct, _) = trainer::evaluate_sketch(&out.model, &sk_te);

        let art = ModelArtifact::new(spec, out.model).unwrap();
        let path = std::env::temp_dir().join(format!(
            "bbml_isess_model_{}_{}.bbm",
            scheme.name(),
            std::process::id()
        ));
        art.save(&path).unwrap();
        let loaded = ModelArtifact::load(&path).unwrap();

        // Round the test rows through the libsvm text format — the raw
        // input `predict` consumes in production.
        let libsvm_path = std::env::temp_dir().join(format!(
            "bbml_isess_test_{}_{}.libsvm",
            scheme.name(),
            std::process::id()
        ));
        bbml::data::libsvm::write_libsvm(&test, &libsvm_path).unwrap();
        let raw =
            bbml::data::libsvm::read_libsvm(&libsvm_path, Some(loaded.spec.dim)).unwrap();
        let pred = predict_artifact(&loaded, &raw, &popt).unwrap();
        assert_eq!(pred.rows, test.n());
        assert_eq!(
            pred.accuracy.to_bits(),
            acc_direct.to_bits(),
            "{scheme}: predict-from-artifact ≡ direct evaluation"
        );
        assert!(pred.accuracy > 0.8, "{scheme}: acc {}", pred.accuracy);

        // Scheme assertion mismatch → InvalidData.
        let wrong = if scheme == Scheme::Bbit {
            Scheme::Vw
        } else {
            Scheme::Bbit
        };
        let err = loaded.assert_scheme(wrong).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&libsvm_path).ok();
    }
}

#[test]
fn cli_lifecycle_train_save_predict_and_stream_resume() {
    // The CI smoke path in-process: train --save-model, predict on the
    // generated corpus file, and checkpoint → resume with equal
    // weights_crc32 in the two reports.
    let base = tmp_dir("cli");
    let s = |v: &[&str]| -> Vec<String> { v.iter().map(|s| s.to_string()).collect() };
    let corpus_dir = base.join("data");
    let model_path = base.join("model.bbm");
    bbml::cli::run_with(&s(&[
        "generate",
        "n_docs=150",
        "dim=1048576",
        "vocab=2000",
        "mean_len=40",
        &format!("out_dir={}", corpus_dir.display()),
    ]))
    .unwrap();
    bbml::cli::run_with(&s(&[
        "train",
        "--scheme",
        "bbit",
        "--k",
        "16",
        "--b",
        "4",
        "--save-model",
        model_path.to_str().unwrap(),
        "n_docs=150",
        "dim=1048576",
        "vocab=2000",
        "mean_len=40",
        &format!("out_dir={}", base.join("train").display()),
    ]))
    .unwrap();
    let pred_dir = base.join("pred");
    bbml::cli::run_with(&s(&[
        "predict",
        "--model",
        model_path.to_str().unwrap(),
        "--data",
        corpus_dir.join("corpus.libsvm").to_str().unwrap(),
        &format!("out_dir={}", pred_dir.display()),
    ]))
    .unwrap();
    let report = std::fs::read_to_string(pred_dir.join("predict_report.json")).unwrap();
    assert!(report.contains("\"scheme\": \"bbit\""), "{report}");
    assert!(report.contains("\"rows\": 150"), "{report}");
    // Asserting the wrong scheme on predict is refused.
    assert!(bbml::cli::run_with(&s(&[
        "predict",
        "--model",
        model_path.to_str().unwrap(),
        "--scheme",
        "vw",
    ]))
    .is_err());

    // Out-of-core: checkpointed full run, then resume from the epoch-1
    // boundary; the reports must agree on the weights fingerprint.
    let store_dir = base.join("sig");
    bbml::cli::run_with(&s(&[
        "hash-store",
        "--k",
        "16",
        "--b",
        "4",
        "--chunk",
        "48",
        "--store",
        store_dir.to_str().unwrap(),
        "n_docs=150",
        "dim=1048576",
        "vocab=2000",
        "mean_len=40",
    ]))
    .unwrap();
    let ckpt_dir = base.join("ckpt");
    let full_dir = base.join("full");
    bbml::cli::run_with(&s(&[
        "train-stream",
        "--backend",
        "pegasos",
        "--epochs",
        "2",
        "--store",
        store_dir.to_str().unwrap(),
        "--checkpoint",
        ckpt_dir.to_str().unwrap(),
        "--ckpt-every",
        "1",
        &format!("out_dir={}", full_dir.display()),
    ]))
    .unwrap();
    let resumed_dir = base.join("resumed");
    bbml::cli::run_with(&s(&[
        "train-stream",
        "--store",
        store_dir.to_str().unwrap(),
        "--resume",
        ckpt_dir.join("ckpt-e0001-s00000.ckpt").to_str().unwrap(),
        &format!("out_dir={}", resumed_dir.display()),
    ]))
    .unwrap();
    let full = std::fs::read_to_string(full_dir.join("stream_report.json")).unwrap();
    let resumed = std::fs::read_to_string(resumed_dir.join("stream_report.json")).unwrap();
    let crc_of = |text: &str| {
        text.lines()
            .find(|l| l.contains("weights_crc32"))
            .unwrap()
            .trim()
            .trim_end_matches(',')
            .rsplit(':')
            .next()
            .unwrap()
            .trim()
            .to_string()
    };
    assert_eq!(
        crc_of(&full),
        crc_of(&resumed),
        "resumed weights fingerprint must match:\n{full}\n{resumed}"
    );
    assert!(resumed.contains("\"resumed\": true"), "{resumed}");
    assert!(full.contains("\"resumed\": false"), "{full}");
    std::fs::remove_dir_all(&base).ok();
}
