//! End-to-end pipeline integration: corpus → shingles → sharded hashing →
//! signatures → training → evaluation, all through the public API.

use bbml::coordinator::pipeline::{hash_corpus, hash_dataset, PipelineOptions};
use bbml::coordinator::trainer::{evaluate, train_signatures, Backend};
use bbml::data::libsvm;
use bbml::data::synth::{generate_corpus, CorpusSampler, SynthConfig};

fn corpus_cfg(n: usize) -> SynthConfig {
    SynthConfig {
        n_docs: n,
        dim: 1 << 22,
        vocab: 10_000,
        mean_len: 80,
        topic_mix: 0.3,
        ..Default::default()
    }
}

#[test]
fn full_path_corpus_to_accuracy() {
    let cfg = corpus_cfg(600);
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.2, 7);
    let opt = PipelineOptions::default();
    let (sig_tr, stats) = hash_dataset(&train, 128, 8, 42, &opt);
    let (sig_te, _) = hash_dataset(&test, 128, 8, 42, &opt);

    // The paper's storage claim: packed data is n·b·k bits.
    let expect_bytes = (sig_tr.n() * 128 * 8).div_ceil(8);
    assert!(stats.output_bytes <= expect_bytes + 8);
    // ...which is a real reduction vs the raw representation.
    assert!(stats.output_bytes * 4 < train.storage_bytes());

    let out = train_signatures(&sig_tr, Backend::SvmDcd, 1.0, 3, None, None).unwrap();
    let (acc, _) = evaluate(&out.model, &sig_te);
    assert!(acc > 0.9, "test accuracy {acc}");
}

#[test]
fn streaming_and_materialized_paths_agree() {
    let cfg = corpus_cfg(200);
    let sampler = CorpusSampler::new(cfg.clone());
    let ds = generate_corpus(&cfg);
    let opt = PipelineOptions {
        threads: 4,
        chunk: 17,
        queue: 2,
    };
    let (a, _) = hash_corpus(&sampler, cfg.n_docs, 32, 4, 9, &opt);
    let (b, _) = hash_dataset(&ds, 32, 4, 9, &opt);
    assert_eq!(a.n(), b.n());
    for i in 0..a.n() {
        assert_eq!(a.row(i), b.row(i), "row {i}");
        assert_eq!(a.label(i), b.label(i));
    }
}

#[test]
fn libsvm_roundtrip_preserves_learning_behaviour() {
    let cfg = corpus_cfg(300);
    let ds = generate_corpus(&cfg);
    let dir = std::env::temp_dir().join("bbml_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corpus.libsvm.gz");
    libsvm::write_libsvm(&ds, &path).unwrap();
    let back = libsvm::read_libsvm(&path, Some(ds.dim())).unwrap();
    assert_eq!(back.n(), ds.n());
    assert_eq!(back.total_nnz(), ds.total_nnz());
    // Hash both and compare signatures — identical input must hash identically.
    let opt = PipelineOptions::default();
    let (s1, _) = hash_dataset(&ds, 16, 8, 5, &opt);
    let (s2, _) = hash_dataset(&back, 16, 8, 5, &opt);
    for i in 0..s1.n() {
        assert_eq!(s1.row(i), s2.row(i));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn accuracy_improves_with_b_and_k() {
    // The central shape of Figures 1/5: more bits and more permutations
    // move hashed-data accuracy toward original-data accuracy.
    let cfg = corpus_cfg(500);
    let ds = generate_corpus(&cfg);
    let (train, test) = ds.train_test_split(0.2, 11);
    let opt = PipelineOptions::default();
    let acc_of = |k: usize, b: u32| {
        let (tr, _) = hash_dataset(&train, k, b, 77, &opt);
        let (te, _) = hash_dataset(&test, k, b, 77, &opt);
        let out = train_signatures(&tr, Backend::SvmDcd, 1.0, 3, None, None).unwrap();
        evaluate(&out.model, &te).0
    };
    let lo = acc_of(16, 1);
    let hi = acc_of(128, 8);
    assert!(
        hi >= lo + 0.02 || hi > 0.97,
        "k=128/b=8 ({hi}) should beat k=16/b=1 ({lo})"
    );
}

#[test]
fn cli_hash_and_config_commands_run() {
    bbml::cli::run_with(&[
        "hash".to_string(),
        "--k".to_string(),
        "16".to_string(),
        "--b".to_string(),
        "4".to_string(),
        "n_docs=100".to_string(),
        "dim=1048576".to_string(),
        "vocab=2000".to_string(),
    ])
    .unwrap();
    bbml::cli::run_with(&["config".to_string()]).unwrap();
}
