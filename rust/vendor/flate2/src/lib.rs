//! Vendored subset of the `flate2` gzip API (the real crate and its zlib
//! backend are unavailable in this offline environment).
//!
//! [`write::GzEncoder`] emits **valid gzip**: a standard header, DEFLATE
//! *stored* (uncompressed) blocks, and the CRC32 + ISIZE trailer — any
//! gzip reader accepts the output (`gzip -d`, Python's `gzip`, the real
//! flate2). [`read::GzDecoder`] parses gzip limited to stored blocks (what
//! this encoder and `gzip -0`-style writers produce) and reports
//! `InvalidData` for Huffman-compressed members; swap this path dependency
//! for the real flate2 to read arbitrary gzip.

use std::io::{self, Read, Write};

/// Compression level knob — accepted for API parity; the stored-block
/// encoder has exactly one "level".
#[derive(Clone, Copy, Debug)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Self {
        Compression(level)
    }
    pub fn fast() -> Self {
        Compression(1)
    }
    pub fn best() -> Self {
        Compression(9)
    }
    pub fn none() -> Self {
        Compression(0)
    }
    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Self {
        Compression(6)
    }
}

/// CRC-32 (reflected, poly 0xEDB88320) — the gzip trailer checksum.
fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &byte in data {
        c ^= byte as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 {
                (c >> 1) ^ 0xEDB8_8320
            } else {
                c >> 1
            };
        }
    }
    !c
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("gzip: {msg}"))
}

fn take(raw: &[u8], pos: usize, n: usize) -> io::Result<&[u8]> {
    raw.get(pos..pos + n).ok_or_else(|| bad("truncated stream"))
}

/// Decode a complete gzip member made of stored deflate blocks.
fn decode_gzip(raw: &[u8]) -> io::Result<Vec<u8>> {
    let hdr = take(raw, 0, 10)?;
    if hdr[0] != 0x1f || hdr[1] != 0x8b {
        return Err(bad("missing magic bytes"));
    }
    if hdr[2] != 8 {
        return Err(bad("unknown compression method"));
    }
    let flg = hdr[3];
    let mut pos = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA
        let xlen = u16::from_le_bytes(take(raw, pos, 2)?.try_into().unwrap()) as usize;
        pos += 2 + xlen;
    }
    for mask in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: zero-terminated strings
        if flg & mask != 0 {
            while take(raw, pos, 1)?[0] != 0 {
                pos += 1;
            }
            pos += 1;
        }
    }
    if flg & 0x02 != 0 {
        // FHCRC
        pos += 2;
    }
    let mut out = Vec::new();
    loop {
        let block_hdr = take(raw, pos, 1)?[0];
        pos += 1;
        let bfinal = block_hdr & 1 != 0;
        match (block_hdr >> 1) & 3 {
            0 => {
                let len = u16::from_le_bytes(take(raw, pos, 2)?.try_into().unwrap());
                let nlen = u16::from_le_bytes(take(raw, pos + 2, 2)?.try_into().unwrap());
                if len != !nlen {
                    return Err(bad("stored block LEN/NLEN mismatch"));
                }
                pos += 4;
                out.extend_from_slice(take(raw, pos, len as usize)?);
                pos += len as usize;
            }
            _ => {
                return Err(bad(
                    "Huffman-compressed member: the vendored flate2 stub reads \
                     stored blocks only (swap in the real flate2)",
                ))
            }
        }
        if bfinal {
            break;
        }
    }
    let crc = u32::from_le_bytes(take(raw, pos, 4)?.try_into().unwrap());
    let trailer_len = u32::from_le_bytes(take(raw, pos + 4, 4)?.try_into().unwrap());
    if crc != crc32(&out) {
        return Err(bad("CRC32 mismatch"));
    }
    if trailer_len != out.len() as u32 {
        return Err(bad("ISIZE mismatch"));
    }
    Ok(out)
}

pub mod write {
    use super::*;

    /// Streaming gzip writer: buffers the payload, then emits header +
    /// stored blocks + trailer on [`GzEncoder::finish`] (or on drop, like
    /// the real flate2).
    pub struct GzEncoder<W: Write> {
        inner: Option<W>,
        buf: Vec<u8>,
        done: bool,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(w: W, _level: Compression) -> Self {
            Self {
                inner: Some(w),
                buf: Vec::new(),
                done: false,
            }
        }

        fn write_stream(&mut self) -> io::Result<()> {
            if self.done {
                return Ok(());
            }
            self.done = true;
            let Some(w) = self.inner.as_mut() else {
                return Ok(());
            };
            // Header: magic, deflate, no flags, mtime 0, XFL 0, OS unknown.
            w.write_all(&[0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 255])?;
            // Non-final stored blocks, ≤ 65535 bytes each.
            for chunk in self.buf.chunks(65_535) {
                let len = chunk.len() as u16;
                w.write_all(&[0x00])?;
                w.write_all(&len.to_le_bytes())?;
                w.write_all(&(!len).to_le_bytes())?;
                w.write_all(chunk)?;
            }
            // Final empty stored block, then CRC32 + ISIZE.
            w.write_all(&[0x01, 0x00, 0x00, 0xFF, 0xFF])?;
            w.write_all(&crc32(&self.buf).to_le_bytes())?;
            w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            w.flush()
        }

        /// Write the gzip stream and return the inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            self.write_stream()?;
            Ok(self.inner.take().expect("finish called twice"))
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    impl<W: Write> Drop for GzEncoder<W> {
        fn drop(&mut self) {
            let _ = self.write_stream();
        }
    }
}

pub mod read {
    use super::*;

    /// Gzip reader (stored blocks only): decodes the whole member on first
    /// read, then serves from memory.
    pub struct GzDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(r: R) -> Self {
            Self {
                inner: Some(r),
                out: Vec::new(),
                pos: 0,
            }
        }

        fn load(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.out = decode_gzip(&raw)?;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.load()?;
            let n = buf.len().min(self.out.len() - self.pos);
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Cursor, Read, Write};

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let gz = enc.finish().unwrap();
        let mut dec = read::GzDecoder::new(Cursor::new(gz));
        let mut back = Vec::new();
        dec.read_to_end(&mut back).unwrap();
        back
    }

    #[test]
    fn roundtrips_various_sizes() {
        for size in [0usize, 1, 100, 65_535, 65_536, 200_000] {
            let data: Vec<u8> = (0..size).map(|i| (i * 31 % 251) as u8).collect();
            assert_eq!(roundtrip(&data), data, "size {size}");
        }
    }

    #[test]
    fn emits_gzip_magic_and_valid_trailer() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::default());
        enc.write_all(b"hello").unwrap();
        let gz = enc.finish().unwrap();
        assert_eq!(&gz[..3], &[0x1f, 0x8b, 8]);
        let n = gz.len();
        assert_eq!(&gz[n - 4..], &5u32.to_le_bytes()); // ISIZE
    }

    #[test]
    fn drop_finishes_the_stream() {
        let mut sink: Vec<u8> = Vec::new();
        {
            let mut enc = write::GzEncoder::new(&mut sink, Compression::fast());
            enc.write_all(b"dropped").unwrap();
        } // drop writes the stream
        let mut dec = read::GzDecoder::new(Cursor::new(sink));
        let mut back = String::new();
        dec.read_to_string(&mut back).unwrap();
        assert_eq!(back, "dropped");
    }

    #[test]
    fn rejects_compressed_blocks_and_garbage() {
        // BTYPE=01 (fixed Huffman) after a valid header.
        let mut fake = vec![0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 255, 0x03];
        fake.extend_from_slice(&[0u8; 8]);
        let mut dec = read::GzDecoder::new(Cursor::new(fake));
        let mut out = Vec::new();
        assert!(dec.read_to_end(&mut out).is_err());
        let mut dec = read::GzDecoder::new(Cursor::new(b"not gzip".to_vec()));
        assert!(dec.read_to_end(&mut out).is_err());
    }

    #[test]
    fn crc_reference_value() {
        // Known CRC-32 of "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
