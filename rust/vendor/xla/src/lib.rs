//! Vendored **stub** of the `xla` (xla-rs) PJRT surface this repository
//! uses. The real crate links `libxla_extension`, which is unavailable in
//! this offline environment; the stub keeps `runtime::client` compiling
//! unchanged while [`PjRtClient::cpu`] reports "unavailable" at runtime, so
//! `Runtime::try_default()` returns `None` and every PJRT test/bench skips
//! gracefully. Swap this path dependency for the real crate to execute the
//! AOT artifacts.

use std::fmt;

/// The stub's error: every entry point fails with this.
#[derive(Clone)]
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("PJRT unavailable: vendored xla stub (swap rust/vendor/xla for the real xla-rs crate)".into())
}

/// Element types the typed entry points accept.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Stub PJRT client — construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub host literal. Construction works (it holds nothing); every
/// data-movement call fails.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("unavailable"));
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
