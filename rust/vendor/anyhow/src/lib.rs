//! Vendored, dependency-free subset of the `anyhow` API (the real crate is
//! unavailable in this offline environment). Covers exactly what this
//! repository uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, `?`-conversion from any `std::error::Error`, and
//! [`Context::context`] / [`Context::with_context`] on results.

use std::fmt;

/// A flattened error: the message plus any context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prefix the error with context (newest first, like anyhow's chain).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion (and
// therefore `?` on io/parse/... errors) coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible computation.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                ::std::stringify!($cond)
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/7a3f")?;
        Ok(())
    }

    fn ensure_fn(x: usize) -> Result<usize> {
        ensure!(x < 10, "x too big: {x}");
        Ok(x)
    }

    fn bail_fn() -> Result<()> {
        bail!("nope: {}", 42)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "), "{e}");
        let e2 = io_fail().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert!(e2.to_string().starts_with("pass 2: "), "{e2}");
    }

    #[test]
    fn macros_format() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {}", 7).to_string(), "x = 7");
        let y = 3;
        assert_eq!(anyhow!("y = {y}").to_string(), "y = 3");
        assert_eq!(ensure_fn(3).unwrap(), 3);
        assert_eq!(ensure_fn(30).unwrap_err().to_string(), "x too big: 30");
        assert_eq!(bail_fn().unwrap_err().to_string(), "nope: 42");
    }
}
